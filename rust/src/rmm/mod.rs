//! Pure-Rust RMM reference: sketches, randomized matmul, variance theory,
//! fast transforms.  This is the *CPU-side* mirror of the Pallas/JAX stack —
//! used for property tests, cross-language golden checks, host baselines in
//! the benches, and the Adelman-style comparison.
//!
//! ## Estimator families
//!
//! Six fused `project_streamed` families share one seed-addressed
//! interface (S is never materialized): the paper's `gauss`,
//! `rademacher`, `dct`, `dft` and `rowsample`, plus `wtacrs` — WTA-CRS
//! (winner-take-all column-row sampling, arXiv 2305.15265) in its
//! data-independent uniform-mass form: half the projection budget buys
//! deterministic distinct winner rows at scale 1, the rest samples the
//! loser complement (see [`sketch::wta_plan`]); its exact closed-form
//! variance is [`variance::d2_wtacrs`].
//!
//! On top of the family axis sits a per-path mode
//! ([`GradPathMode`], arXiv 2602.14701): `avjp-<family>` sketch strings
//! select the approximate-VJP configuration, which applies the sketch
//! only on the grad-weight path and keeps the grad-input VJP exact —
//! [`backward_linear`] implements both modes host-side.
//!
//! ## Closed-loop variance control
//!
//! [`controller`] replaces the static (family, ρ) grid axis: given a
//! per-step memory budget (`--mem-budget` / config `rmm.mem_budget`, the
//! allowed fraction of the exact ρ=1 residual), it evaluates the
//! Lemma-2.2 closed forms ([`variance::d2_family`]) for every candidate
//! (family, ρ) online and picks the minimum-variance feasible
//! configuration per layer.  The choice sequence is a pure function of
//! (probe tensors, budget), so sweep fragments recording it stay
//! byte-identical for any worker/thread count.

pub mod controller;
pub mod fft;
pub mod sketch;
pub mod variance;

pub use sketch::SketchKind;

use crate::tensor::{matmul, matmul_at, Tensor};

/// Exact ∂W = Yᵀ X (paper eq. 3; baseline path).
pub fn exact_grad_w(y: &Tensor, x: &Tensor) -> Tensor {
    matmul_at(y, x)
}

/// Algorithm 1 forward side: X_proj = Sᵀ X.
pub fn project(kind: SketchKind, x: &Tensor, b_proj: usize, seed: (u32, u32)) -> Tensor {
    sketch::project_streamed(kind, x, b_proj, seed)
}

/// Algorithm 1 backward side: ∂W ≈ (Sᵀ Y)ᵀ X_proj (paper eq. 4).
pub fn rmm_grad_w(
    kind: SketchKind,
    y: &Tensor,
    x_proj: &Tensor,
    seed: (u32, u32),
) -> Tensor {
    let y_proj = sketch::project_streamed(kind, y, x_proj.rows, seed);
    matmul_at(&y_proj, x_proj)
}

/// Which backward paths the sketch touches (per-path mode,
/// arXiv 2602.14701).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradPathMode {
    /// Fully-sketched backward: dY is projected once and reused on both
    /// paths — ∂W ≈ (SᵀdY)ᵀX_proj and ∂X ≈ S·(SᵀdY)·W (both unbiased,
    /// one projection pass over dY).
    Sketched,
    /// Approximate-VJP: the sketch touches only the grad-weight path;
    /// grad-input is the exact VJP ∂X = dY·W.
    ExactGradInput,
}

/// An estimator configuration on the sweep's sketch-string axis: a
/// family, optionally wrapped in the approximate-VJP per-path mode via
/// the `avjp-` prefix (e.g. `avjp-gauss`).  Parsing is case-insensitive
/// and unknown names are reported with the full valid list.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EstimatorSpec {
    pub kind: SketchKind,
    pub mode: GradPathMode,
}

impl EstimatorSpec {
    pub fn parse(s: &str) -> anyhow::Result<EstimatorSpec> {
        let lower = s.trim().to_ascii_lowercase();
        if let Some(rest) = lower.strip_prefix("avjp-") {
            Ok(EstimatorSpec {
                kind: SketchKind::parse_or_err(rest)?,
                mode: GradPathMode::ExactGradInput,
            })
        } else {
            Ok(EstimatorSpec {
                kind: SketchKind::parse_or_err(&lower)?,
                mode: GradPathMode::Sketched,
            })
        }
    }

    pub fn approx_vjp(&self) -> bool {
        self.mode == GradPathMode::ExactGradInput
    }

    pub fn name(&self) -> String {
        if self.approx_vjp() {
            format!("avjp-{}", self.kind.name())
        } else {
            self.kind.name().to_string()
        }
    }
}

/// One linear-layer backward under an estimator configuration.
///
/// Convention: Y = X·Wᵀ with W:(M,N), X:(B,N), dY:(B,M); the stored
/// residual is X_proj = SᵀX (b_proj × N).  Returns (∂W, ∂X):
/// ∂W ≈ (SᵀdY)ᵀX_proj on both modes; ∂X is the exact dY·W under
/// [`GradPathMode::ExactGradInput`] and the lifted S·(SᵀdY)·W under
/// [`GradPathMode::Sketched`].
pub fn backward_linear(
    spec: EstimatorSpec,
    dy: &Tensor,
    x_proj: &Tensor,
    w: &Tensor,
    seed: (u32, u32),
) -> (Tensor, Tensor) {
    let dy_proj = sketch::project_streamed(spec.kind, dy, x_proj.rows, seed);
    let grad_w = matmul_at(&dy_proj, x_proj);
    let grad_x = match spec.mode {
        GradPathMode::ExactGradInput => matmul(dy, w),
        GradPathMode::Sketched => {
            matmul(&sketch::lift_streamed(spec.kind, &dy_proj, dy.rows, seed), w)
        }
    };
    (grad_w, grad_x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::philox::PhiloxStream;

    fn randt(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut s = PhiloxStream::new(seed, 3);
        Tensor::from_fn(rows, cols, |_, _| s.next_normal())
    }

    #[test]
    fn rmm_grad_is_unbiased() {
        let x = randt(16, 4, 1);
        let y = randt(16, 6, 2);
        let exact = exact_grad_w(&y, &x);
        for kind in SketchKind::ALL {
            let trials = 800;
            let mut acc = Tensor::zeros(6, 4);
            for t in 0..trials {
                let seed = (t as u32 * 31 + 1, 9);
                let xp = project(kind, &x, 8, seed);
                let g = rmm_grad_w(kind, &y, &xp, seed);
                acc.add_assign(&g);
            }
            acc.scale(1.0 / trials as f32);
            let scale = exact.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            assert!(
                acc.max_abs_diff(&exact) < 0.25 * scale.max(1.0),
                "{kind:?}: {}",
                acc.max_abs_diff(&exact)
            );
        }
    }

    #[test]
    fn rmm_grad_matches_explicit_sketch_algebra() {
        let x = randt(12, 3, 3);
        let y = randt(12, 5, 4);
        let seed = (21, 22);
        for kind in SketchKind::ALL {
            let s = sketch::sketch(kind, 12, 6, seed);
            let want = matmul_at(
                &crate::tensor::matmul_at(&s, &y),
                &crate::tensor::matmul_at(&s, &x),
            ); // (Sᵀy)ᵀ(Sᵀx)
            let got = rmm_grad_w(kind, &y, &project(kind, &x, 6, seed), seed);
            assert!(got.max_abs_diff(&want) < 1e-3, "{kind:?}");
        }
    }

    #[test]
    fn estimator_spec_parses_both_axes() {
        let e = EstimatorSpec::parse("gauss").unwrap();
        assert_eq!(e.kind, SketchKind::Gauss);
        assert!(!e.approx_vjp());
        assert_eq!(e.name(), "gauss");
        let e = EstimatorSpec::parse("AVJP-WtaCrs").unwrap();
        assert_eq!(e.kind, SketchKind::WtaCrs);
        assert!(e.approx_vjp());
        assert_eq!(e.name(), "avjp-wtacrs");
        let err = EstimatorSpec::parse("avjp-bogus").unwrap_err().to_string();
        assert!(err.contains("'bogus'") && err.contains("wtacrs"), "{err}");
        assert!(EstimatorSpec::parse("none").is_err());
    }

    #[test]
    fn avjp_backward_keeps_grad_input_exact() {
        let x = randt(16, 4, 11);
        let dy = randt(16, 6, 12);
        let w = randt(6, 4, 13); // (M, N)
        let seed = (31, 32);
        for kind in SketchKind::ALL {
            let xp = project(kind, &x, 8, seed);
            let spec =
                EstimatorSpec { kind, mode: GradPathMode::ExactGradInput };
            let (gw, gx) = backward_linear(spec, &dy, &xp, &w, seed);
            // grad-input is bit-for-bit the exact VJP — the sketch never
            // touches that path
            assert_eq!(gx.data, matmul(&dy, &w).data, "{kind:?}");
            // grad-weight is the same sketched estimator both modes share
            assert_eq!(gw.data, rmm_grad_w(kind, &dy, &xp, seed).data, "{kind:?}");
        }
    }

    #[test]
    fn sketched_backward_grad_input_is_unbiased() {
        let x = randt(12, 3, 21);
        let dy = randt(12, 5, 22);
        let w = randt(5, 3, 23);
        let exact = matmul(&dy, &w);
        let xp0 = project(SketchKind::Gauss, &x, 6, (1, 2));
        let trials = 800;
        let mut acc = Tensor::zeros(12, 3);
        for t in 0..trials {
            let seed = (t as u32 * 37 + 5, 13);
            let xp = project(SketchKind::Gauss, &x, 6, seed);
            let spec = EstimatorSpec {
                kind: SketchKind::Gauss,
                mode: GradPathMode::Sketched,
            };
            let (_, gx) = backward_linear(spec, &dy, &xp, &w, seed);
            assert_eq!((gx.rows, gx.cols), (12, 3));
            assert_eq!((xp.rows, xp.cols), (xp0.rows, xp0.cols));
            acc.add_assign(&gx);
        }
        acc.scale(1.0 / trials as f32);
        let scale = exact.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!(
            acc.max_abs_diff(&exact) < 0.25 * scale.max(1.0),
            "{}",
            acc.max_abs_diff(&exact)
        );
    }

    #[test]
    fn full_width_gauss_sketch_approximates_exact() {
        // With b_proj = many ≫ B the estimate concentrates near exact.
        let x = randt(8, 3, 5);
        let y = randt(8, 4, 6);
        let exact = exact_grad_w(&y, &x);
        let xp = project(SketchKind::Gauss, &x, 4096, (7, 8));
        let g = rmm_grad_w(SketchKind::Gauss, &y, &xp, (7, 8));
        let scale = exact.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!(g.max_abs_diff(&exact) < 0.15 * scale, "{}", g.max_abs_diff(&exact));
    }
}
