//! Artifact manifest: the typed contract between the AOT compile path
//! (python/compile/aot.py) and the Rust runtime.  Parses
//! `artifacts/manifest.json` into variant/entry/arg-spec types so the
//! coordinator stays generic over model geometry, ρ, sketch kind and the
//! residual interface.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

pub const MANIFEST_VERSION: i64 = 2;

/// Value dtype of one argument/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "float32" => Dtype::F32,
            "int32" => Dtype::I32,
            "uint32" => Dtype::U32,
            other => bail!("unsupported dtype '{other}'"),
        })
    }

    pub fn size(&self) -> usize {
        4
    }

    #[cfg(feature = "xla")]
    pub fn element_type(&self) -> xla::ElementType {
        match self {
            Dtype::F32 => xla::ElementType::F32,
            Dtype::I32 => xla::ElementType::S32,
            Dtype::U32 => xla::ElementType::U32,
        }
    }
}

/// Semantic role of an argument/output (drives the trainer's plumbing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Param,
    Tokens,
    Mask,
    Labels,
    Seed,
    Residual,
    Grad,
    Metric,
    Logits,
    Probe,
}

impl Role {
    pub fn parse(s: &str) -> Result<Role> {
        Ok(match s {
            "param" => Role::Param,
            "tokens" => Role::Tokens,
            "mask" => Role::Mask,
            "labels" => Role::Labels,
            "seed" => Role::Seed,
            "residual" => Role::Residual,
            "grad" => Role::Grad,
            "metric" => Role::Metric,
            "logits" => Role::Logits,
            "probe" => Role::Probe,
            other => bail!("unknown role '{other}'"),
        })
    }
}

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    pub role: Role,
}

impl ArgSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype.size()
    }

    fn from_json(j: &Json) -> Result<ArgSpec> {
        let name = j.get("name").as_str().context("spec.name")?.to_string();
        let shape = j
            .get("shape")
            .as_arr()
            .context("spec.shape")?
            .iter()
            .map(|d| d.as_usize().context("shape dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(ArgSpec {
            name,
            shape,
            dtype: Dtype::parse(j.get("dtype").as_str().context("spec.dtype")?)?,
            role: Role::parse(j.get("role").as_str().context("spec.role")?)?,
        })
    }
}

/// One lowered entry point (fwd / bwd / eval).
#[derive(Debug, Clone)]
pub struct Entry {
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
}

impl Entry {
    fn from_json(j: &Json) -> Result<Entry> {
        let specs = |key: &str| -> Result<Vec<ArgSpec>> {
            j.get(key)
                .as_arr()
                .with_context(|| format!("entry.{key}"))?
                .iter()
                .map(ArgSpec::from_json)
                .collect()
        };
        Ok(Entry {
            file: j.get("file").as_str().context("entry.file")?.to_string(),
            args: specs("args")?,
            outputs: specs("outputs")?,
        })
    }

    pub fn residual_args(&self) -> impl Iterator<Item = &ArgSpec> {
        self.args.iter().filter(|a| a.role == Role::Residual)
    }

    pub fn residual_outputs(&self) -> impl Iterator<Item = &ArgSpec> {
        self.outputs.iter().filter(|a| a.role == Role::Residual)
    }
}

/// The static model geometry the variant was lowered with.
#[derive(Debug, Clone)]
pub struct VariantConfig {
    pub vocab_size: usize,
    pub seq_len: usize,
    pub batch_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub n_classes: usize,
    pub regression: bool,
    pub rho: f64,
    pub sketch: String,
    pub use_kernels: bool,
    pub probe_layer: i64,
}

impl VariantConfig {
    fn from_json(j: &Json) -> Result<VariantConfig> {
        let u = |k: &str| j.get(k).as_usize().with_context(|| format!("config.{k}"));
        Ok(VariantConfig {
            vocab_size: u("vocab_size")?,
            seq_len: u("seq_len")?,
            batch_size: u("batch_size")?,
            d_model: u("d_model")?,
            n_heads: u("n_heads")?,
            n_layers: u("n_layers")?,
            d_ff: u("d_ff")?,
            n_classes: u("n_classes")?,
            regression: j.get("regression").as_bool().context("config.regression")?,
            rho: j.get("rho").as_f64().context("config.rho")?,
            sketch: j.get("sketch").as_str().context("config.sketch")?.to_string(),
            use_kernels: j.get("use_kernels").as_bool().unwrap_or(false),
            probe_layer: j.get("probe_layer").as_i64().unwrap_or(-1),
        })
    }

    pub fn geometry(&self) -> crate::memory::ModelGeometry {
        crate::memory::ModelGeometry {
            vocab_size: self.vocab_size,
            seq_len: self.seq_len,
            batch_size: self.batch_size,
            d_model: self.d_model,
            n_heads: self.n_heads,
            n_layers: self.n_layers,
            d_ff: self.d_ff,
            n_classes: self.n_classes,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Variant {
    pub name: String,
    pub config: VariantConfig,
    pub rows: usize,
    pub b_proj: usize,
    pub init_params: String,
    pub param_count: usize,
    pub entries: BTreeMap<String, Entry>,
}

impl Variant {
    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .with_context(|| format!("variant '{}' has no '{name}' entry", self.name))
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: BTreeMap<String, Variant>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {path:?} — run `make artifacts` first")
        })?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        let version = j.get("version").as_i64().context("manifest.version")?;
        if version != MANIFEST_VERSION {
            bail!("manifest version {version} != expected {MANIFEST_VERSION}");
        }
        let mut variants = BTreeMap::new();
        for (name, vj) in j.get("variants").as_obj().context("manifest.variants")? {
            let mut entries = BTreeMap::new();
            for (ename, ej) in vj.get("entries").as_obj().context("entries")? {
                entries.insert(ename.clone(), Entry::from_json(ej)?);
            }
            variants.insert(
                name.clone(),
                Variant {
                    name: name.clone(),
                    config: VariantConfig::from_json(vj.get("config"))?,
                    rows: vj.get("rows").as_usize().context("rows")?,
                    b_proj: vj.get("b_proj").as_usize().context("b_proj")?,
                    init_params: vj
                        .get("init_params")
                        .as_str()
                        .context("init_params")?
                        .to_string(),
                    param_count: vj.get("param_count").as_usize().context("param_count")?,
                    entries,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), variants })
    }

    pub fn variant(&self, name: &str) -> Result<&Variant> {
        self.variants.get(name).with_context(|| {
            format!(
                "no variant '{name}' in manifest (have: {})",
                self.variants.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    pub fn hlo_path(&self, entry: &Entry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    pub fn init_params_path(&self, v: &Variant) -> PathBuf {
        self.dir.join(&v.init_params)
    }

    /// Load the raw-f32 initial parameter blob for a variant, split into
    /// per-parameter vectors following the entry's param arg specs.
    pub fn load_init_params(&self, v: &Variant) -> Result<Vec<Vec<f32>>> {
        let entry = v
            .entries
            .values()
            .next()
            .with_context(|| format!("variant '{}' has no entries", v.name))?;
        let path = self.init_params_path(v);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        let mut out = Vec::new();
        let mut off = 0usize;
        for spec in entry.args.iter().filter(|a| a.role == Role::Param) {
            let n = spec.elements();
            let end = off + n * 4;
            if end > bytes.len() {
                bail!("init params {path:?} too short at '{}'", spec.name);
            }
            let vals: Vec<f32> = bytes[off..end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            out.push(vals);
            off = end;
        }
        if off != bytes.len() {
            bail!(
                "init params {path:?}: {} trailing bytes (spec mismatch)",
                bytes.len() - off
            );
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_and_role_parse() {
        assert_eq!(Dtype::parse("float32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("int32").unwrap(), Dtype::I32);
        assert!(Dtype::parse("float64").is_err());
        assert_eq!(Role::parse("residual").unwrap(), Role::Residual);
        assert!(Role::parse("whatever").is_err());
    }

    #[test]
    fn argspec_bytes() {
        let j = Json::parse(
            r#"{"name":"x","shape":[4,8],"dtype":"float32","role":"residual"}"#,
        )
        .unwrap();
        let s = ArgSpec::from_json(&j).unwrap();
        assert_eq!(s.elements(), 32);
        assert_eq!(s.bytes(), 128);
    }

    #[test]
    fn scalar_spec_has_one_element() {
        let j = Json::parse(r#"{"name":"loss","shape":[],"dtype":"float32","role":"metric"}"#)
            .unwrap();
        assert_eq!(ArgSpec::from_json(&j).unwrap().elements(), 1);
    }

    #[test]
    fn manifest_version_checked() {
        let dir = std::env::temp_dir().join(format!("mani_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"version": 999, "variants": {}}"#)
            .unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
