//! PJRT execution engine: loads HLO-text artifacts, compiles them once,
//! and executes them with host values.  This is the only module that talks
//! to the `xla` crate; everything above works with `HostValue`s and specs.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serialized protos use 64-bit instruction ids which xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The `xla` crate is only present in the AOT toolchain image, so the PJRT
//! engine is gated behind the off-by-default `xla` cargo feature.  Without
//! it a stub `Engine` with the identical API is compiled: construction
//! succeeds (so CLI plumbing and host-side benches run), but `execute`
//! fails fast with a pointed message.

use std::collections::HashMap;
#[cfg(feature = "xla")]
use std::path::Path;
#[cfg(feature = "xla")]
use std::time::Instant;

use anyhow::{bail, Result};

use super::manifest::{Dtype, Entry, Manifest};
#[cfg(feature = "xla")]
use super::manifest::ArgSpec;

/// A host-side tensor value (flattened, row-major) ready for upload.
#[derive(Debug, Clone)]
pub enum HostValue {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl HostValue {
    pub fn len(&self) -> usize {
        match self {
            HostValue::F32(v) => v.len(),
            HostValue::I32(v) => v.len(),
            HostValue::U32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostValue::F32(_) => Dtype::F32,
            HostValue::I32(_) => Dtype::I32,
            HostValue::U32(_) => Dtype::U32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostValue::F32(v) => Ok(v),
            _ => bail!("expected f32 host value"),
        }
    }

    #[cfg(feature = "xla")]
    fn bytes(&self) -> &[u8] {
        match self {
            HostValue::F32(v) => bytemuck_f32(v),
            HostValue::I32(v) => bytemuck_i32(v),
            HostValue::U32(v) => bytemuck_u32(v),
        }
    }

    /// Upload to a literal with the spec's shape.
    #[cfg(feature = "xla")]
    pub fn to_literal(&self, spec: &ArgSpec) -> Result<xla::Literal> {
        if self.len() != spec.elements() {
            bail!(
                "'{}': value has {} elements, spec {:?} wants {}",
                spec.name,
                self.len(),
                spec.shape,
                spec.elements()
            );
        }
        if self.dtype() != spec.dtype {
            bail!("'{}': dtype mismatch", spec.name);
        }
        xla::Literal::create_from_shape_and_untyped_data(
            spec.dtype.element_type(),
            &spec.shape,
            self.bytes(),
        )
        .map_err(|e| anyhow::anyhow!("literal upload '{}': {e:?}", spec.name))
    }

    /// Download from a literal according to its dtype.
    #[cfg(feature = "xla")]
    pub fn from_literal(lit: &xla::Literal, dtype: Dtype) -> Result<HostValue> {
        Ok(match dtype {
            Dtype::F32 => HostValue::F32(
                lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            ),
            Dtype::I32 => HostValue::I32(
                lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            ),
            Dtype::U32 => HostValue::U32(
                lit.to_vec::<u32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            ),
        })
    }
}

#[cfg(feature = "xla")]
fn bytemuck_f32(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}
#[cfg(feature = "xla")]
fn bytemuck_i32(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}
#[cfg(feature = "xla")]
fn bytemuck_u32(v: &[u32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// Timing + cache counters for the perf pass (EXPERIMENTS.md §Perf).
/// Cache counters mirror the engine's [`ExeCache`]: a *hit* is an
/// `execute`/`load` that reused an already-compiled executable — across
/// warm-session sweep cells of the same variant every step after the
/// first is a hit — while a *miss* forces a compile and an *eviction*
/// retires the least-recently-used executable past the cache capacity.
#[derive(Debug, Default, Clone, Copy)]
pub struct EngineStats {
    pub compiles: usize,
    pub compile_s: f64,
    pub executions: usize,
    pub execute_s: f64,
    pub upload_s: f64,
    pub download_s: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
}

/// Env var bounding the number of cached executables (0 / unset =
/// unbounded).  A warm session batching many variants through one
/// engine is the first consumer that can outgrow an unbounded cache.
pub const EXE_CACHE_CAP_ENV: &str = "RMM_EXE_CACHE_CAP";

/// Strict parse of the cap value: an operator who *set* the variable to
/// bound memory must not silently get an unbounded cache from a typo.
/// Routed through the shared knob parser so the error shape stays
/// uniform with `RMM_POOL_GRAIN` / `RMM_SIMD`.
fn parse_cache_cap(v: &str) -> Result<usize> {
    crate::util::env::parse_usize_with_zero(EXE_CACHE_CAP_ENV, "0 = unbounded", v)
}

fn cache_cap_from_env() -> Result<usize> {
    match std::env::var(EXE_CACHE_CAP_ENV) {
        Err(_) => Ok(0),
        Ok(v) => parse_cache_cap(&v),
    }
}

/// LRU cache for compiled executables, keyed by artifact path.  Generic
/// over the executable type so the stub engine (and the `rmm_micro`
/// schedule simulation) exercise the exact structure the PJRT engine
/// runs — capacity 0 means unbounded (no evictions).
pub struct ExeCache<T> {
    map: HashMap<String, (T, u64)>,
    tick: u64,
    capacity: usize,
}

impl<T> ExeCache<T> {
    pub fn new(capacity: usize) -> ExeCache<T> {
        ExeCache { map: HashMap::new(), tick: 0, capacity }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Drop every cached executable (the cold-path reset; counters in
    /// the owner's stats are cumulative and unaffected).
    pub fn clear(&mut self) {
        self.map.clear();
    }

    /// Look an executable up, refreshing its recency on a hit.
    pub fn get(&mut self, key: &str) -> Option<&T> {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(key) {
            Some((value, last_use)) => {
                *last_use = tick;
                Some(value)
            }
            None => None,
        }
    }

    /// Insert an executable, evicting least-recently-used entries while
    /// the cache exceeds its capacity.  Returns how many were evicted.
    pub fn insert(&mut self, key: String, value: T) -> u64 {
        self.tick += 1;
        self.map.insert(key, (value, self.tick));
        let mut evicted = 0u64;
        if self.capacity > 0 {
            while self.map.len() > self.capacity {
                let oldest = self
                    .map
                    .iter()
                    .min_by_key(|(_, (_, last_use))| *last_use)
                    .map(|(k, _)| k.clone());
                match oldest {
                    Some(k) => {
                        self.map.remove(&k);
                        evicted += 1;
                    }
                    None => break,
                }
            }
        }
        evicted
    }
}

/// PJRT CPU engine with a compile cache keyed by artifact path.
#[cfg(feature = "xla")]
pub struct Engine {
    client: xla::PjRtClient,
    cache: ExeCache<xla::PjRtLoadedExecutable>,
    pub stats: EngineStats,
}

#[cfg(feature = "xla")]
impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Engine {
            client,
            cache: ExeCache::new(cache_cap_from_env()?),
            stats: EngineStats::default(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Drop every cached executable — the session layer calls this per
    /// run under `--session-cache off`, so the "cold path" control arm
    /// really recompiles instead of riding engine-lifetime reuse.
    pub fn reset_cache(&mut self) {
        self.cache.clear();
    }

    /// Compile (or fetch from cache) the executable for an entry.
    pub fn load(&mut self, manifest: &Manifest, entry: &Entry) -> Result<()> {
        let path = manifest.hlo_path(entry);
        let key = path.to_string_lossy().to_string();
        self.ensure_compiled(&key, &path)?;
        Ok(())
    }

    /// Cache lookup + stats accounting; compiles on a miss.
    fn ensure_compiled(&mut self, key: &str, path: &Path) -> Result<()> {
        if self.cache.get(key).is_some() {
            self.stats.cache_hits += 1;
            return Ok(());
        }
        self.stats.cache_misses += 1;
        let exe = self.compile_file(path)?;
        self.stats.cache_evictions += self.cache.insert(key.to_string(), exe);
        Ok(())
    }

    fn compile_file(&mut self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow::anyhow!("parsing HLO {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {path:?}: {e:?}"))?;
        self.stats.compiles += 1;
        self.stats.compile_s += t0.elapsed().as_secs_f64();
        Ok(exe)
    }

    /// Execute an entry with host values matched 1:1 to `entry.args`.
    /// Returns outputs matched 1:1 to `entry.outputs`.
    pub fn execute(
        &mut self,
        manifest: &Manifest,
        entry: &Entry,
        args: &[HostValue],
    ) -> Result<Vec<HostValue>> {
        if args.len() != entry.args.len() {
            bail!("expected {} args, got {}", entry.args.len(), args.len());
        }
        let path = manifest.hlo_path(entry);
        let key = path.to_string_lossy().to_string();
        self.ensure_compiled(&key, &path)?;

        let t_up = Instant::now();
        let literals: Vec<xla::Literal> = args
            .iter()
            .zip(&entry.args)
            .map(|(v, spec)| v.to_literal(spec))
            .collect::<Result<_>>()?;
        self.stats.upload_s += t_up.elapsed().as_secs_f64();

        let exe = self.cache.get(&key).unwrap();
        let t_ex = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {key}: {e:?}"))?;
        self.stats.executions += 1;
        self.stats.execute_s += t_ex.elapsed().as_secs_f64();

        let t_dn = Instant::now();
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e:?}"))?;
        // aot.py lowers with return_tuple=True → always a tuple literal.
        let parts = tuple.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        if parts.len() != entry.outputs.len() {
            bail!("expected {} outputs, got {}", entry.outputs.len(), parts.len());
        }
        let out = parts
            .iter()
            .zip(&entry.outputs)
            .map(|(lit, spec)| HostValue::from_literal(lit, spec.dtype))
            .collect::<Result<Vec<_>>>()?;
        self.stats.download_s += t_dn.elapsed().as_secs_f64();
        Ok(out)
    }
}

/// Stub engine compiled when the `xla` feature is off: same API — down
/// to the executable cache-stat accounting, so session-layer plumbing
/// and tests observe real hit/miss/evict numbers — but any attempt to
/// compile or execute an artifact fails with a pointed message.
#[cfg(not(feature = "xla"))]
pub struct Engine {
    cache: ExeCache<()>,
    pub stats: EngineStats,
}

#[cfg(not(feature = "xla"))]
impl Engine {
    pub fn cpu() -> Result<Engine> {
        Ok(Engine {
            cache: ExeCache::new(cache_cap_from_env()?),
            stats: EngineStats::default(),
        })
    }

    pub fn platform(&self) -> String {
        "stub (built without the `xla` feature)".to_string()
    }

    /// See the xla engine's `reset_cache`: the cold-path per-run reset.
    pub fn reset_cache(&mut self) {
        self.cache.clear();
    }

    /// Record the cache access the real engine would have made (the
    /// "compile" is free here), then refuse: the stub can account but
    /// never execute.
    fn touch_cache(&mut self, manifest: &Manifest, entry: &Entry) {
        let key = manifest.hlo_path(entry).to_string_lossy().to_string();
        if self.cache.get(&key).is_some() {
            self.stats.cache_hits += 1;
        } else {
            self.stats.cache_misses += 1;
            self.stats.cache_evictions += self.cache.insert(key, ());
        }
    }

    pub fn load(&mut self, manifest: &Manifest, entry: &Entry) -> Result<()> {
        self.touch_cache(manifest, entry);
        bail!(
            "PJRT runtime unavailable: this binary was built without the \
             `xla` cargo feature (see rust/Cargo.toml); host-side kernels, \
             benches and tests still work"
        )
    }

    pub fn execute(
        &mut self,
        manifest: &Manifest,
        entry: &Entry,
        _args: &[HostValue],
    ) -> Result<Vec<HostValue>> {
        self.touch_cache(manifest, entry);
        bail!(
            "PJRT runtime unavailable: this binary was built without the \
             `xla` cargo feature (see rust/Cargo.toml); host-side kernels, \
             benches and tests still work"
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hostvalue_basics() {
        let v = HostValue::F32(vec![1.0; 6]);
        assert_eq!(v.len(), 6);
        assert!(!v.is_empty());
        assert_eq!(v.dtype(), Dtype::F32);
        assert!(v.as_f32().is_ok());
        assert!(HostValue::I32(vec![1]).as_f32().is_err());
    }

    #[test]
    fn exe_cache_counts_hits_and_lru_evicts() {
        let mut c: ExeCache<usize> = ExeCache::new(2);
        assert!(c.is_empty());
        assert!(c.get("a").is_none());
        assert_eq!(c.insert("a".into(), 1), 0);
        assert_eq!(c.insert("b".into(), 2), 0);
        assert_eq!(c.get("a"), Some(&1)); // refresh a: b is now LRU
        assert_eq!(c.insert("c".into(), 3), 1, "capacity 2 must evict one");
        assert_eq!(c.len(), 2);
        assert!(c.get("b").is_none(), "LRU entry b must be the one evicted");
        assert_eq!(c.get("a"), Some(&1));
        assert_eq!(c.get("c"), Some(&3));
    }

    #[test]
    fn exe_cache_capacity_zero_never_evicts() {
        let mut c: ExeCache<usize> = ExeCache::new(0);
        for i in 0..64usize {
            assert_eq!(c.insert(format!("k{i}"), i), 0);
        }
        assert_eq!(c.len(), 64);
        c.clear();
        assert!(c.is_empty());
    }

    #[test]
    fn cache_cap_parses_strictly() {
        assert_eq!(parse_cache_cap("0").unwrap(), 0);
        assert_eq!(parse_cache_cap(" 32 ").unwrap(), 32);
        for bad in ["1k", "-1", "", "unbounded"] {
            let err = parse_cache_cap(bad).unwrap_err();
            assert!(format!("{err}").contains(EXE_CACHE_CAP_ENV), "{bad}: {err}");
        }
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_engine_constructs_but_refuses_to_execute() {
        let mut e = Engine::cpu().unwrap();
        assert!(e.platform().contains("stub"));
        let manifest =
            Manifest { dir: std::path::PathBuf::new(), variants: Default::default() };
        let entry = Entry { file: "x.hlo".into(), args: vec![], outputs: vec![] };
        let err = e.execute(&manifest, &entry, &[]).unwrap_err();
        assert!(format!("{err}").contains("xla"), "{err}");
        // the stub still accounts cache traffic like the real engine:
        // first touch misses, the repeat hits
        assert_eq!((e.stats.cache_hits, e.stats.cache_misses), (0, 1));
        let _ = e.execute(&manifest, &entry, &[]);
        assert_eq!((e.stats.cache_hits, e.stats.cache_misses), (1, 1));
        // the cold-path reset makes the next touch miss again
        e.reset_cache();
        let _ = e.execute(&manifest, &entry, &[]);
        assert_eq!((e.stats.cache_hits, e.stats.cache_misses), (1, 2));
    }
}

#[cfg(all(test, feature = "xla"))]
mod xla_tests {
    use super::*;
    use crate::runtime::manifest::{ArgSpec, Role};

    fn spec(shape: &[usize], dtype: Dtype) -> ArgSpec {
        ArgSpec {
            name: "t".into(),
            shape: shape.to_vec(),
            dtype,
            role: Role::Residual,
        }
    }

    #[test]
    fn hostvalue_shape_checks() {
        let v = HostValue::F32(vec![1.0; 6]);
        assert!(v.to_literal(&spec(&[2, 3], Dtype::F32)).is_ok());
        assert!(v.to_literal(&spec(&[2, 4], Dtype::F32)).is_err());
        assert!(v.to_literal(&spec(&[6], Dtype::I32)).is_err());
    }

    #[test]
    fn literal_roundtrip() {
        let v = HostValue::I32(vec![1, -2, 3, 4]);
        let lit = v.to_literal(&spec(&[2, 2], Dtype::I32)).unwrap();
        let back = HostValue::from_literal(&lit, Dtype::I32).unwrap();
        match back {
            HostValue::I32(xs) => assert_eq!(xs, vec![1, -2, 3, 4]),
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn u32_roundtrip() {
        let v = HostValue::U32(vec![7, u32::MAX]);
        let lit = v.to_literal(&spec(&[2], Dtype::U32)).unwrap();
        match HostValue::from_literal(&lit, Dtype::U32).unwrap() {
            HostValue::U32(xs) => assert_eq!(xs, vec![7, u32::MAX]),
            _ => panic!(),
        }
    }
}
