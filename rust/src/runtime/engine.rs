//! PJRT execution engine: loads HLO-text artifacts, compiles them once,
//! and executes them with host values.  This is the only module that talks
//! to the `xla` crate; everything above works with `HostValue`s and specs.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serialized protos use 64-bit instruction ids which xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The `xla` crate is only present in the AOT toolchain image, so the PJRT
//! engine is gated behind the off-by-default `xla` cargo feature.  Without
//! it a stub `Engine` with the identical API is compiled: construction
//! succeeds (so CLI plumbing and host-side benches run), but `execute`
//! fails fast with a pointed message.

#[cfg(feature = "xla")]
use std::collections::HashMap;
#[cfg(feature = "xla")]
use std::path::Path;
#[cfg(feature = "xla")]
use std::time::Instant;

use anyhow::{bail, Result};

use super::manifest::{Dtype, Entry, Manifest};
#[cfg(feature = "xla")]
use super::manifest::ArgSpec;

/// A host-side tensor value (flattened, row-major) ready for upload.
#[derive(Debug, Clone)]
pub enum HostValue {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl HostValue {
    pub fn len(&self) -> usize {
        match self {
            HostValue::F32(v) => v.len(),
            HostValue::I32(v) => v.len(),
            HostValue::U32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostValue::F32(_) => Dtype::F32,
            HostValue::I32(_) => Dtype::I32,
            HostValue::U32(_) => Dtype::U32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostValue::F32(v) => Ok(v),
            _ => bail!("expected f32 host value"),
        }
    }

    #[cfg(feature = "xla")]
    fn bytes(&self) -> &[u8] {
        match self {
            HostValue::F32(v) => bytemuck_f32(v),
            HostValue::I32(v) => bytemuck_i32(v),
            HostValue::U32(v) => bytemuck_u32(v),
        }
    }

    /// Upload to a literal with the spec's shape.
    #[cfg(feature = "xla")]
    pub fn to_literal(&self, spec: &ArgSpec) -> Result<xla::Literal> {
        if self.len() != spec.elements() {
            bail!(
                "'{}': value has {} elements, spec {:?} wants {}",
                spec.name,
                self.len(),
                spec.shape,
                spec.elements()
            );
        }
        if self.dtype() != spec.dtype {
            bail!("'{}': dtype mismatch", spec.name);
        }
        xla::Literal::create_from_shape_and_untyped_data(
            spec.dtype.element_type(),
            &spec.shape,
            self.bytes(),
        )
        .map_err(|e| anyhow::anyhow!("literal upload '{}': {e:?}", spec.name))
    }

    /// Download from a literal according to its dtype.
    #[cfg(feature = "xla")]
    pub fn from_literal(lit: &xla::Literal, dtype: Dtype) -> Result<HostValue> {
        Ok(match dtype {
            Dtype::F32 => HostValue::F32(
                lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            ),
            Dtype::I32 => HostValue::I32(
                lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            ),
            Dtype::U32 => HostValue::U32(
                lit.to_vec::<u32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            ),
        })
    }
}

#[cfg(feature = "xla")]
fn bytemuck_f32(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}
#[cfg(feature = "xla")]
fn bytemuck_i32(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}
#[cfg(feature = "xla")]
fn bytemuck_u32(v: &[u32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// Timing counters for the perf pass (EXPERIMENTS.md §Perf).
#[derive(Debug, Default, Clone, Copy)]
pub struct EngineStats {
    pub compiles: usize,
    pub compile_s: f64,
    pub executions: usize,
    pub execute_s: f64,
    pub upload_s: f64,
    pub download_s: f64,
}

/// PJRT CPU engine with a compile cache keyed by artifact path.
#[cfg(feature = "xla")]
pub struct Engine {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    pub stats: EngineStats,
}

#[cfg(feature = "xla")]
impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Engine { client, cache: HashMap::new(), stats: EngineStats::default() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for an entry.
    pub fn load(&mut self, manifest: &Manifest, entry: &Entry) -> Result<()> {
        let path = manifest.hlo_path(entry);
        let key = path.to_string_lossy().to_string();
        if self.cache.contains_key(&key) {
            return Ok(());
        }
        let exe = self.compile_file(&path)?;
        self.cache.insert(key, exe);
        Ok(())
    }

    fn compile_file(&mut self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow::anyhow!("parsing HLO {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {path:?}: {e:?}"))?;
        self.stats.compiles += 1;
        self.stats.compile_s += t0.elapsed().as_secs_f64();
        Ok(exe)
    }

    /// Execute an entry with host values matched 1:1 to `entry.args`.
    /// Returns outputs matched 1:1 to `entry.outputs`.
    pub fn execute(
        &mut self,
        manifest: &Manifest,
        entry: &Entry,
        args: &[HostValue],
    ) -> Result<Vec<HostValue>> {
        if args.len() != entry.args.len() {
            bail!("expected {} args, got {}", entry.args.len(), args.len());
        }
        let path = manifest.hlo_path(entry);
        let key = path.to_string_lossy().to_string();
        if !self.cache.contains_key(&key) {
            let exe = self.compile_file(&path)?;
            self.cache.insert(key.clone(), exe);
        }

        let t_up = Instant::now();
        let literals: Vec<xla::Literal> = args
            .iter()
            .zip(&entry.args)
            .map(|(v, spec)| v.to_literal(spec))
            .collect::<Result<_>>()?;
        self.stats.upload_s += t_up.elapsed().as_secs_f64();

        let exe = self.cache.get(&key).unwrap();
        let t_ex = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {key}: {e:?}"))?;
        self.stats.executions += 1;
        self.stats.execute_s += t_ex.elapsed().as_secs_f64();

        let t_dn = Instant::now();
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e:?}"))?;
        // aot.py lowers with return_tuple=True → always a tuple literal.
        let parts = tuple.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        if parts.len() != entry.outputs.len() {
            bail!("expected {} outputs, got {}", entry.outputs.len(), parts.len());
        }
        let out = parts
            .iter()
            .zip(&entry.outputs)
            .map(|(lit, spec)| HostValue::from_literal(lit, spec.dtype))
            .collect::<Result<Vec<_>>>()?;
        self.stats.download_s += t_dn.elapsed().as_secs_f64();
        Ok(out)
    }
}

/// Stub engine compiled when the `xla` feature is off: same API, but any
/// attempt to compile or execute an artifact fails with a pointed message.
#[cfg(not(feature = "xla"))]
pub struct Engine {
    pub stats: EngineStats,
}

#[cfg(not(feature = "xla"))]
impl Engine {
    pub fn cpu() -> Result<Engine> {
        Ok(Engine { stats: EngineStats::default() })
    }

    pub fn platform(&self) -> String {
        "stub (built without the `xla` feature)".to_string()
    }

    pub fn load(&mut self, _manifest: &Manifest, _entry: &Entry) -> Result<()> {
        bail!(
            "PJRT runtime unavailable: this binary was built without the \
             `xla` cargo feature (see rust/Cargo.toml); host-side kernels, \
             benches and tests still work"
        )
    }

    pub fn execute(
        &mut self,
        _manifest: &Manifest,
        _entry: &Entry,
        _args: &[HostValue],
    ) -> Result<Vec<HostValue>> {
        bail!(
            "PJRT runtime unavailable: this binary was built without the \
             `xla` cargo feature (see rust/Cargo.toml); host-side kernels, \
             benches and tests still work"
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hostvalue_basics() {
        let v = HostValue::F32(vec![1.0; 6]);
        assert_eq!(v.len(), 6);
        assert!(!v.is_empty());
        assert_eq!(v.dtype(), Dtype::F32);
        assert!(v.as_f32().is_ok());
        assert!(HostValue::I32(vec![1]).as_f32().is_err());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_engine_constructs_but_refuses_to_execute() {
        let mut e = Engine::cpu().unwrap();
        assert!(e.platform().contains("stub"));
        let err = e
            .execute(
                &Manifest { dir: std::path::PathBuf::new(), variants: Default::default() },
                &Entry { file: "x.hlo".into(), args: vec![], outputs: vec![] },
                &[],
            )
            .unwrap_err();
        assert!(format!("{err}").contains("xla"), "{err}");
    }
}

#[cfg(all(test, feature = "xla"))]
mod xla_tests {
    use super::*;
    use crate::runtime::manifest::{ArgSpec, Role};

    fn spec(shape: &[usize], dtype: Dtype) -> ArgSpec {
        ArgSpec {
            name: "t".into(),
            shape: shape.to_vec(),
            dtype,
            role: Role::Residual,
        }
    }

    #[test]
    fn hostvalue_shape_checks() {
        let v = HostValue::F32(vec![1.0; 6]);
        assert!(v.to_literal(&spec(&[2, 3], Dtype::F32)).is_ok());
        assert!(v.to_literal(&spec(&[2, 4], Dtype::F32)).is_err());
        assert!(v.to_literal(&spec(&[6], Dtype::I32)).is_err());
    }

    #[test]
    fn literal_roundtrip() {
        let v = HostValue::I32(vec![1, -2, 3, 4]);
        let lit = v.to_literal(&spec(&[2, 2], Dtype::I32)).unwrap();
        let back = HostValue::from_literal(&lit, Dtype::I32).unwrap();
        match back {
            HostValue::I32(xs) => assert_eq!(xs, vec![1, -2, 3, 4]),
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn u32_roundtrip() {
        let v = HostValue::U32(vec![7, u32::MAX]);
        let lit = v.to_literal(&spec(&[2], Dtype::U32)).unwrap();
        match HostValue::from_literal(&lit, Dtype::U32).unwrap() {
            HostValue::U32(xs) => assert_eq!(xs, vec![7, u32::MAX]),
            _ => panic!(),
        }
    }
}
