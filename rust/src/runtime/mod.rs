//! Runtime: PJRT engine + artifact manifest (the AOT boundary).
//!
//! Python appears only at build time (`make artifacts`); this module loads
//! the resulting HLO-text artifacts and executes them on the PJRT CPU
//! client from the training hot path.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, EngineStats, ExeCache, HostValue};
pub use manifest::{ArgSpec, Dtype, Entry, Manifest, Role, Variant, VariantConfig};
