//! Determinism / property suite for the sweep orchestrator
//! (`sweep::` — grid, shard, merge, resume) and the async-prefetch
//! batcher.
//!
//! The contract under test (see `sweep/mod.rs` for the canonical prose):
//! a sharded sweep over deterministic cells merges to a report
//! **byte-identical** to the serial sweep for any shard count and any
//! completion order; resume-after-kill reruns exactly the missing cells
//! and reproduces the same bytes; and the prefetched `Batcher` emits the
//! exact batch sequence of the synchronous iterator.  All orchestration
//! tests run over the deterministic mock cell runner, so they exercise
//! the real shard/merge/resume machinery without artifacts or an engine
//! — including one test that drives the actual `repro sweep-worker`
//! subprocess contract via `CARGO_BIN_EXE_repro`.

use std::path::{Path, PathBuf};

use rmmlinear::config::TrainConfig;
use rmmlinear::data::{Batch, Batcher, PrefetchBatcher, Split, Task, TaskGen, Tokenizer};
use rmmlinear::sweep::{self, merge, resume, Cell, Shard, SweepSpec};
use rmmlinear::util::json::Json;
use rmmlinear::util::prop::prop_check;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("rmm_prop_sweep_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A mock grid exercising every cell axis (task × ρ × sketch × seed).
fn mock_spec(n_tasks: usize, n_rhos: usize, n_seeds: usize) -> SweepSpec {
    let mut spec = SweepSpec::new("mock", TrainConfig::default());
    for r in 0..n_rhos {
        for t in 0..n_tasks {
            for s in 0..n_seeds {
                spec.push(
                    format!("v{t}_r{r}"),
                    format!("task{t}"),
                    1.0 / (r + 1) as f64,
                    if t % 2 == 0 { "gauss" } else { "dct" },
                    s as u64,
                    t * 8,
                );
            }
        }
    }
    spec
}

/// Merged report bytes for whatever fragments `dir` holds.
fn report(dir: &Path, spec: &SweepSpec) -> String {
    Json::Arr(merge::merge(dir, spec).expect("sweep incomplete")).to_string_pretty()
}

/// Run the whole grid serially into `dir` and return the report bytes.
fn run_serial(dir: &Path, spec: &SweepSpec) -> String {
    resume::prepare(dir, spec, false).unwrap();
    sweep::run_shard(dir, spec, Shard::SERIAL, &mut |c, _| Ok(sweep::mock_cell(c)))
        .unwrap();
    report(dir, spec)
}

#[test]
fn sharded_sweep_is_byte_identical_to_serial() {
    let spec = mock_spec(4, 3, 2); // 24 cells
    let serial_dir = tmp_dir("serial_ref");
    let serial = run_serial(&serial_dir, &spec);

    for shards in [1usize, 2, 3, 7] {
        let dir = tmp_dir(&format!("sharded_{shards}"));
        resume::prepare(&dir, &spec, false).unwrap();
        // run the shards in *reverse* order to prove completion order
        // cannot matter
        for s in (0..shards).rev() {
            let shard = Shard { index: s, of: shards };
            sweep::run_shard(&dir, &spec, shard, &mut |c, _| Ok(sweep::mock_cell(c)))
                .unwrap();
        }
        assert_eq!(
            report(&dir, &spec),
            serial,
            "{shards}-shard report differs from serial"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
    std::fs::remove_dir_all(&serial_dir).unwrap();
}

#[test]
fn pooled_in_process_shards_match_serial() {
    let spec = mock_spec(3, 3, 2); // 18 cells
    let serial_dir = tmp_dir("pooled_ref");
    let serial = run_serial(&serial_dir, &spec);
    for shards in [2usize, 5] {
        let dir = tmp_dir(&format!("pooled_{shards}"));
        resume::prepare(&dir, &spec, false).unwrap();
        sweep::run_shards_pooled(&dir, &spec, shards, &|c| Ok(sweep::mock_cell(c)))
            .unwrap();
        assert_eq!(report(&dir, &spec), serial);
        std::fs::remove_dir_all(&dir).unwrap();
    }
    std::fs::remove_dir_all(&serial_dir).unwrap();
}

#[test]
fn resume_after_kill_reruns_only_missing_cells() {
    prop_check("resume reproduces the report", 10, |g| {
        let spec = mock_spec(g.usize_in(2, 4), g.usize_in(1, 3), g.usize_in(1, 2));
        let dir = tmp_dir(&format!("resume_{}", g.case_seed));
        let full = run_serial(&dir, &spec);

        // "kill": drop a random half of the cell manifests
        let cdir = resume::cells_dir(&dir);
        let mut dropped = 0usize;
        for cell in &spec.cells {
            if g.bool() {
                std::fs::remove_file(merge::fragment_path(&cdir, cell)).unwrap();
                dropped += 1;
            }
        }
        assert_eq!(
            resume::completed(&dir, &spec).iter().filter(|&&c| c).count(),
            spec.cells.len() - dropped
        );

        // resume: prepare(resume=true) keeps survivors; rerun must touch
        // exactly the dropped cells
        resume::prepare(&dir, &spec, true).unwrap();
        let mut reran = 0usize;
        sweep::run_shard(&dir, &spec, Shard::SERIAL, &mut |c, _| {
            reran += 1;
            Ok(sweep::mock_cell(c))
        })
        .unwrap();
        assert_eq!(reran, dropped, "resume reran the wrong cell count");
        assert_eq!(report(&dir, &spec), full, "resumed report differs");
        std::fs::remove_dir_all(&dir).unwrap();
    });
}

#[test]
fn corrupt_or_stale_fragments_are_rerun_not_merged() {
    let spec = mock_spec(3, 2, 1); // 6 cells
    let dir = tmp_dir("corrupt");
    let full = run_serial(&dir, &spec);
    let cdir = resume::cells_dir(&dir);

    // truncated JSON (a worker killed mid-write before the rename would
    // normally prevent this; simulate a torn file anyway)
    std::fs::write(merge::fragment_path(&cdir, &spec.cells[1]), "{\"cell\":").unwrap();
    // stale fragment: a manifest answering for a *different* grid cell
    let mut stale = spec.cells[3].clone();
    stale.variant = "from_an_older_grid".into();
    merge::write_fragment(&cdir, &spec, &stale, &Json::num(666.0)).unwrap();

    assert!(merge::merge(&dir, &spec).is_err(), "invalid fragments must not merge");

    resume::prepare(&dir, &spec, true).unwrap();
    let mut reran = Vec::new();
    sweep::run_shard(&dir, &spec, Shard::SERIAL, &mut |c, _| {
        reran.push(c.index);
        Ok(sweep::mock_cell(c))
    })
    .unwrap();
    assert_eq!(reran, vec![1, 3], "exactly the invalid cells rerun");
    assert_eq!(report(&dir, &spec), full);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The real multi-process path: spawn the actual `repro` binary with the
/// `sweep-worker --dir D --shard i/N` contract and verify the merged
/// report is byte-identical to the in-process serial run — the
/// acceptance check behind `bench-table2 --shards 3` vs `--shards 1`
/// (real cells are deterministic in everything but timing fields; the
/// mock grid makes the identity exact and checkable).
#[test]
fn worker_subprocesses_match_serial_byte_for_byte() {
    let spec = mock_spec(4, 3, 1); // 12 cells
    let serial_dir = tmp_dir("subproc_ref");
    let serial = run_serial(&serial_dir, &spec);

    for shards in [1usize, 3] {
        let dir = tmp_dir(&format!("subproc_{shards}"));
        resume::prepare(&dir, &spec, false).unwrap();
        let mut children = Vec::new();
        for i in 0..shards {
            let child = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
                .arg("sweep-worker")
                .arg("--dir")
                .arg(&dir)
                .arg("--shard")
                .arg(format!("{i}/{shards}"))
                .spawn()
                .expect("spawning repro sweep-worker");
            children.push(child);
        }
        for mut child in children {
            let status = child.wait().unwrap();
            assert!(status.success(), "worker exited {status}");
        }
        assert_eq!(
            report(&dir, &spec),
            serial,
            "{shards} worker processes differ from serial"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
    std::fs::remove_dir_all(&serial_dir).unwrap();
}

// ---------------------------------------------------------------------------
// Prefetch batching: bit-identity with the synchronous iterator
// ---------------------------------------------------------------------------

fn assert_batches_equal(a: &Batch, b: &Batch, ctx: &str) {
    assert_eq!(a.tokens, b.tokens, "{ctx}: tokens");
    assert_eq!(a.mask, b.mask, "{ctx}: mask");
    assert_eq!(a.labels_i, b.labels_i, "{ctx}: labels_i");
    assert_eq!(a.labels_f, b.labels_f, "{ctx}: labels_f");
    assert_eq!(a.valid, b.valid, "{ctx}: valid");
    assert_eq!(a.batch_size, b.batch_size, "{ctx}: batch_size");
    assert_eq!(a.seq_len, b.seq_len, "{ctx}: seq_len");
}

#[test]
fn prefetched_batcher_yields_exact_sync_sequence_at_every_depth() {
    prop_check("prefetch bit-identity", 25, |g| {
        let task = Task::ALL[g.usize_in(0, Task::ALL.len() - 1)];
        let split = if g.bool() { Split::Train } else { Split::Dev };
        let bsz = g.usize_in(1, 48);
        let seed = g.usize_in(0, 10_000) as u64;
        let epoch = g.usize_in(0, 3) as u64;
        let depth = g.usize_in(1, 5);
        let tok = Tokenizer::new(256);
        let gen = TaskGen::new(task, &tok, 24, seed);
        let sync: Vec<Batch> = Batcher::new(&gen, split, bsz, epoch).collect();
        let pre: Vec<Batch> =
            PrefetchBatcher::with_depth(&gen, split, bsz, epoch, depth).collect();
        assert_eq!(sync.len(), pre.len(), "{task:?} bsz={bsz} depth={depth}");
        for (i, (a, b)) in sync.iter().zip(&pre).enumerate() {
            assert_batches_equal(
                a,
                b,
                &format!("{task:?} bsz={bsz} depth={depth} batch={i}"),
            );
        }
    });
}

// ---------------------------------------------------------------------------
// RunResult JSON round-trip: byte-stable, NaN-free (the num_or_null pin)
// ---------------------------------------------------------------------------

fn skipped_run_result() -> rmmlinear::bench_harness::runner::RunResult {
    rmmlinear::bench_harness::runner::RunResult {
        variant: "small_cls2_r100_gauss".into(),
        task: "cola".into(),
        rho: 1.0,
        sketch: "gauss".into(),
        // every skippable measurement skipped: NaN must serialize as null
        score: f64::NAN,
        final_train_loss: f64::NAN,
        steps: 0,
        wall_s: 0.125,
        samples_per_s: 128.0,
        peak_residual_bytes: 4096,
        backend: "packed".into(),
        host_exact_ms: f64::NAN,
        host_rmm_ms: f64::NAN,
        pool_threads: 4,
        pool_tasks: 17,
        pool_steals: 3,
        exe_cache_hits: 0,
        exe_cache_misses: 0,
        train_losses: vec![],
        eval_losses: vec![],
        probe_series: vec![],
    }
}

#[test]
fn runresult_json_roundtrip_is_byte_stable_and_nan_free() {
    let r = skipped_run_result();
    let encoded = r.to_json().to_string_pretty();
    assert!(
        !encoded.contains("NaN") && !encoded.contains("inf"),
        "skipped measurements leaked a non-JSON literal:\n{encoded}"
    );
    let parsed = Json::parse(&encoded)
        .expect("RunResult JSON must parse back (sweep fragments depend on it)");
    assert!(parsed.get("score").is_null());
    assert!(parsed.get("final_train_loss").is_null());
    assert!(parsed.get("host_exact_ms").is_null());
    assert!(parsed.get("host_rmm_ms").is_null());
    assert_eq!(parsed.get("peak_residual_bytes").as_usize(), Some(4096));
    // encode → parse → re-encode is byte-stable
    assert_eq!(parsed.to_string_pretty(), encoded);
    // and idempotent through a second cycle
    let again = Json::parse(&parsed.to_string_pretty()).unwrap();
    assert_eq!(again.to_string_pretty(), encoded);
}

#[test]
fn runresult_roundtrips_inside_a_sweep_fragment() {
    // the exact path a real sweep takes: RunResult → fragment → merge
    let mut spec = SweepSpec::new("table2", TrainConfig::default());
    spec.push("small_cls2_r100_gauss", "cola", 1.0, "gauss", 42, 0);
    let dir = tmp_dir("fragment_rt");
    resume::prepare(&dir, &spec, false).unwrap();
    let r = skipped_run_result().to_json();
    merge::write_fragment(&resume::cells_dir(&dir), &spec, &spec.cells[0], &r).unwrap();
    let merged = merge::merge(&dir, &spec).unwrap();
    assert_eq!(merged.len(), 1);
    assert_eq!(merged[0].to_string_pretty(), r.to_string_pretty());
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// Shard algebra on real grid shapes
// ---------------------------------------------------------------------------

#[test]
fn shard_sets_partition_the_grid() {
    let spec = mock_spec(5, 4, 2); // 40 cells
    for shards in [1usize, 2, 3, 7] {
        let mut seen = vec![0usize; spec.cells.len()];
        for s in 0..shards {
            let shard = Shard { index: s, of: shards };
            for c in spec.cells.iter().filter(|c| shard.owns(c.index)) {
                seen[c.index] += 1;
            }
        }
        assert!(seen.iter().all(|&n| n == 1), "shards={shards}: {seen:?}");
    }
}

#[test]
fn cell_identity_drives_fragment_validation() {
    // each field of the cell participates in the resume-validation match
    let base = Cell {
        index: 0,
        variant: "v".into(),
        task: "cola".into(),
        rho: 0.5,
        sketch: "gauss".into(),
        seed: 1,
        batch: 0,
    };
    let dir = tmp_dir("cell_identity");
    let cdir = resume::cells_dir(&dir);
    std::fs::create_dir_all(&cdir).unwrap();
    let spec = SweepSpec::new("mock", TrainConfig::default());
    merge::write_fragment(&cdir, &spec, &base, &Json::num(1.0)).unwrap();
    assert!(merge::read_fragment(&cdir, &spec, &base).is_some());
    // the embedded train config participates in validation too
    let mut retrained = SweepSpec::new("mock", TrainConfig::default());
    retrained.train.steps += 1;
    assert!(
        merge::read_fragment(&cdir, &retrained, &base).is_none(),
        "changed train config should invalidate the fragment"
    );
    for (i, mutate) in [
        Box::new(|c: &mut Cell| c.variant = "w".into()) as Box<dyn Fn(&mut Cell)>,
        Box::new(|c: &mut Cell| c.task = "sst2".into()),
        Box::new(|c: &mut Cell| c.rho = 0.2),
        Box::new(|c: &mut Cell| c.sketch = "dct".into()),
        Box::new(|c: &mut Cell| c.seed = 2),
        Box::new(|c: &mut Cell| c.batch = 8),
    ]
    .iter()
    .enumerate()
    {
        let mut other = base.clone();
        mutate(&mut other);
        assert!(
            merge::read_fragment(&cdir, &spec, &other).is_none(),
            "mutation {i} should invalidate the fragment"
        );
    }
    std::fs::remove_dir_all(&dir).unwrap();
}
