//! Warm-session determinism suite: the per-worker `Session` layer
//! (`crate::session`) must be **observation-free** — a warm worker
//! commits byte-identical results to the cold path for every cell,
//! regardless of cell order, worker count, claim interleaving or
//! `--session-cache` setting — and the affinity-aware dynamic scheduler
//! must keep the exact-single-cover property while grouping
//! same-variant cells.
//!
//! The engine-free `mockdata` grid (`sweep::selftest_data_spec`) drives
//! the real data path: tokenizer + dataset caches, the depth-configured
//! prefetch pipeline, and FNV digests over every generated batch, so a
//! single leaked bit anywhere in the warm path fails the byte-identity
//! assertions.  The trainer half (init-param reuse) is pinned against a
//! synthetic in-memory manifest.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Barrier};

use rmmlinear::bench_harness::runner::run_cell;
use rmmlinear::config::TrainConfig;
use rmmlinear::coordinator::{Trainer, TrainerSetup};
use rmmlinear::data::Task;
use rmmlinear::runtime::{
    ArgSpec, Dtype, Engine, Entry, Manifest, Role, Variant, VariantConfig,
};
use rmmlinear::session::Session;
use rmmlinear::sweep::{self, merge, resume, DynamicConfig, Shard, SweepSpec};
use rmmlinear::util::json::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("rmm_prop_session_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn report(dir: &Path, spec: &SweepSpec) -> String {
    Json::Arr(merge::merge(dir, spec).expect("sweep incomplete")).to_string_pretty()
}

/// Cold reference: a fresh caching-off session runs the grid serially.
fn run_serial_cold(dir: &Path, spec: &SweepSpec) -> String {
    resume::prepare(dir, spec, false).unwrap();
    let mut session = Session::data_only(false);
    sweep::run_shard(dir, spec, Shard::SERIAL, &mut |c, ctx| {
        run_cell(&mut session, spec, c, ctx)
    })
    .unwrap();
    report(dir, spec)
}

// ---------------------------------------------------------------------------
// Warm vs cold byte-identity over the data grid
// ---------------------------------------------------------------------------

#[test]
fn warm_sessions_match_cold_serial_for_worker_counts_1_2_3_7() {
    let spec = sweep::selftest_data_spec();
    let serial_dir = tmp_dir("warm_ref");
    let serial = run_serial_cold(&serial_dir, &spec);

    for workers in [1usize, 2, 3, 7] {
        for caching in [true, false] {
            let dir = tmp_dir(&format!("warm_{workers}_{caching}"));
            resume::prepare(&dir, &spec, false).unwrap();
            let start = Barrier::new(workers);
            let ran: Vec<Vec<usize>> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..workers)
                    .map(|w| {
                        let (start, spec, dir) = (&start, &spec, &dir);
                        s.spawn(move || {
                            let mut session =
                                Session::data_only(caching);
                            let cfg = DynamicConfig::new(&format!("w{w}"), 60_000);
                            start.wait();
                            sweep::run_dynamic(dir, spec, &cfg, &mut |c, ctx| {
                                run_cell(&mut session, spec, c, ctx)
                            })
                            .expect("dynamic session worker failed")
                            .ran
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            let mut cover: Vec<usize> = ran.iter().flatten().copied().collect();
            cover.sort_unstable();
            assert_eq!(
                cover,
                (0..spec.cells.len()).collect::<Vec<_>>(),
                "{workers} workers (caching={caching}) must cover the grid exactly once"
            );
            assert_eq!(
                report(&dir, &spec),
                serial,
                "{workers}-worker warm sweep (caching={caching}) differs from cold serial"
            );
            std::fs::remove_dir_all(&dir).unwrap();
        }
    }
    std::fs::remove_dir_all(&serial_dir).unwrap();
}

#[test]
fn cell_results_are_independent_of_grid_order_and_warm_history() {
    // The same logical cells laid out in two different canonical orders:
    // a warm session accumulates different cache state along each order,
    // and every cell's committed result must still be identical.
    let forward = sweep::selftest_data_spec();
    let mut reversed = SweepSpec::new("mockdata", forward.train.clone());
    for cell in forward.cells.iter().rev() {
        reversed.push(
            cell.variant.clone(),
            cell.task.clone(),
            cell.rho,
            cell.sketch.clone(),
            cell.seed,
            cell.batch,
        );
    }

    let mut by_key: Vec<BTreeMap<(String, u64, usize), String>> = Vec::new();
    for (tag, spec) in [("fwd", &forward), ("rev", &reversed)] {
        let dir = tmp_dir(&format!("order_{tag}"));
        resume::prepare(&dir, spec, false).unwrap();
        let mut session = Session::data_only(true);
        sweep::run_shard(&dir, spec, Shard::SERIAL, &mut |c, ctx| {
            run_cell(&mut session, spec, c, ctx)
        })
        .unwrap();
        let results = merge::merge(&dir, spec).unwrap();
        let map = spec
            .cells
            .iter()
            .zip(&results)
            .map(|(c, r)| {
                ((c.task.clone(), c.seed, c.batch), r.to_string_pretty())
            })
            .collect();
        by_key.push(map);
        std::fs::remove_dir_all(&dir).unwrap();
    }
    assert_eq!(
        by_key[0], by_key[1],
        "per-cell results must not depend on grid order / warm history"
    );
}

#[test]
fn data_grid_worker_subprocesses_match_cold_serial() {
    // The released-binary path CI smokes: real `sweep-worker` processes
    // with warm sessions over the data grid vs the in-process cold run.
    let spec = sweep::selftest_data_spec();
    let serial_dir = tmp_dir("subproc_ref");
    let serial = run_serial_cold(&serial_dir, &spec);

    let dir = tmp_dir("subproc");
    resume::prepare(&dir, &spec, false).unwrap();
    let mut children = Vec::new();
    for _ in 0..2 {
        let child = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
            .args(["sweep-worker", "--dir"])
            .arg(&dir)
            .args(["--schedule", "dynamic", "--session-cache", "on"])
            .stderr(std::process::Stdio::null())
            .spawn()
            .expect("spawning repro sweep-worker (mockdata)");
        children.push(child);
    }
    for mut child in children {
        let status = child.wait().unwrap();
        assert!(status.success(), "mockdata worker exited {status}");
    }
    assert_eq!(report(&dir, &spec), serial, "warm subprocess sweep differs");
    std::fs::remove_dir_all(&serial_dir).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn warm_session_actually_reuses_caches_across_cells() {
    // Not just harmless — the caches must really be hit: the data grid
    // shares one vocab across all cells and repeats (task, seed) pairs
    // across the rho axis, so both cache layers must see traffic.
    let spec = sweep::selftest_data_spec();
    let dir = tmp_dir("reuse");
    resume::prepare(&dir, &spec, false).unwrap();
    let mut session = Session::data_only(true);
    sweep::run_shard(&dir, &spec, Shard::SERIAL, &mut |c, ctx| {
        run_cell(&mut session, &spec, c, ctx)
    })
    .unwrap();
    assert!(
        session.stats.tokenizer_hits > 0,
        "shared-vocab cells must hit the tokenizer cache: {:?}",
        session.stats
    );
    assert!(
        session.stats.dev_hits > 0,
        "same-(task, seed) cells across rho must hit the dev cache: {:?}",
        session.stats
    );

    // the cold control never hits
    let dir2 = tmp_dir("reuse_cold");
    resume::prepare(&dir2, &spec, false).unwrap();
    let mut cold = Session::data_only(false);
    sweep::run_shard(&dir2, &spec, Shard::SERIAL, &mut |c, ctx| {
        run_cell(&mut cold, &spec, c, ctx)
    })
    .unwrap();
    assert_eq!(cold.stats.tokenizer_hits, 0);
    assert_eq!(cold.stats.dev_hits, 0);
    std::fs::remove_dir_all(&dir).unwrap();
    std::fs::remove_dir_all(&dir2).unwrap();
}

// ---------------------------------------------------------------------------
// Trainer half: warm setup reuse is byte-identical and leak-free
// ---------------------------------------------------------------------------

/// A synthetic two-parameter manifest with a real on-disk init blob —
/// enough to drive `TrainerSetup`/`Trainer` construction without AOT
/// artifacts or an engine.
fn synth_manifest(dir: &Path) -> Manifest {
    let mut bytes = Vec::new();
    for i in 0..9 {
        bytes.extend_from_slice(&(i as f32 * 0.5 - 1.0).to_le_bytes());
    }
    std::fs::write(dir.join("init.bin"), &bytes).unwrap();
    let fwd = Entry {
        file: "fwd.hlo".into(),
        args: vec![
            ArgSpec {
                name: "head.w".into(),
                shape: vec![2, 3],
                dtype: Dtype::F32,
                role: Role::Param,
            },
            ArgSpec {
                name: "head.b".into(),
                shape: vec![3],
                dtype: Dtype::F32,
                role: Role::Param,
            },
            ArgSpec {
                name: "tokens".into(),
                shape: vec![4, 8],
                dtype: Dtype::I32,
                role: Role::Tokens,
            },
        ],
        outputs: vec![],
    };
    let config = VariantConfig {
        vocab_size: 64,
        seq_len: 8,
        batch_size: 4,
        d_model: 8,
        n_heads: 2,
        n_layers: 1,
        d_ff: 16,
        n_classes: 2,
        regression: false,
        rho: 1.0,
        sketch: "gauss".into(),
        use_kernels: false,
        probe_layer: -1,
    };
    let variant = Variant {
        name: "v_test".into(),
        config,
        rows: 32,
        b_proj: 16,
        init_params: "init.bin".into(),
        param_count: 9,
        entries: BTreeMap::from([("fwd".to_string(), fwd)]),
    };
    Manifest {
        dir: dir.to_path_buf(),
        variants: BTreeMap::from([("v_test".to_string(), variant)]),
    }
}

#[test]
fn warm_trainer_setup_is_byte_identical_to_cold_and_leak_free() {
    let dir = tmp_dir("trainer_setup");
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = synth_manifest(&dir);
    let variant = manifest.variant("v_test").unwrap();
    let cfg = TrainConfig::default();

    // cold path
    let cold = Trainer::new(&manifest, variant, Task::Cola, cfg.clone()).unwrap();
    // setup loaded twice from disk is identical (pure in the manifest)
    assert_eq!(
        TrainerSetup::load(&manifest, variant).unwrap(),
        TrainerSetup::load(&manifest, variant).unwrap()
    );

    // warm path through a session: the setup is cached once …
    let mut session = Session::new(Engine::cpu().unwrap(), synth_manifest(&dir), true);
    let setup_a = session.trainer_setup("v_test").unwrap();
    let setup_b = session.trainer_setup("v_test").unwrap();
    assert!(Arc::ptr_eq(&setup_a, &setup_b), "warm setup must be shared");
    assert_eq!(session.stats.setup_hits, 1);
    assert_eq!(session.stats.setup_misses, 1);

    // … and warm construction equals cold, byte for byte
    let (_engine, m) = session.engine_manifest().unwrap();
    let v = m.variant("v_test").unwrap();
    let mut warm =
        Trainer::from_setup(m, v, &setup_a, Task::Cola, cfg.clone()).unwrap();
    assert_eq!(warm.params, cold.params);
    assert_eq!(warm.param_names, cold.param_names);
    assert_eq!(warm.step_seed(), cold.step_seed());

    // training one warm cell must not leak into the next: trash the warm
    // trainer's params, rebuild from the same setup, re-check pristine
    warm.params[0][0] += 42.0;
    warm.params[1][2] = f32::NAN;
    drop(warm);
    let warm2 = Trainer::from_setup(m, v, &setup_a, Task::Cola, cfg.clone()).unwrap();
    assert_eq!(warm2.params, cold.params, "cell state leaked through the warm setup");

    // a mismatched setup/variant pair is rejected, not silently accepted
    let bad = TrainerSetup { variant_name: "other".into(), ..(*setup_a).clone() };
    assert!(Trainer::from_setup(m, v, &bad, Task::Cola, cfg.clone()).is_err());

    // caching off: every call reloads (no sharing), same bytes
    let mut cold_session =
        Session::new(Engine::cpu().unwrap(), synth_manifest(&dir), false);
    let s1 = cold_session.trainer_setup("v_test").unwrap();
    let s2 = cold_session.trainer_setup("v_test").unwrap();
    assert!(!Arc::ptr_eq(&s1, &s2), "caching off must not share setups");
    assert_eq!(*s1, *s2);
    assert_eq!(cold_session.stats.setup_misses, 2);

    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// Fleet half: the shared on-disk artifact cache warm-starts a *fresh*
// session (a new worker process joining mid-sweep) byte-identically
// ---------------------------------------------------------------------------

#[test]
fn shared_artifact_cache_warm_starts_a_fresh_session_byte_identically() {
    let dir = tmp_dir("artifact_fleet");
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = synth_manifest(&dir);
    let variant = manifest.variant("v_test").unwrap();
    let cfg = TrainConfig::default();

    // cold reference: no cache of any kind
    let cold = Trainer::new(&manifest, variant, Task::Cola, cfg.clone()).unwrap();

    // session A (first worker on the mount) publishes the setup blob
    let mut a = Session::new(Engine::cpu().unwrap(), synth_manifest(&dir), true);
    a.set_artifact_cache(Some(sweep::fleet::ArtifactCache::open(&dir).unwrap()));
    let setup_a = a.trainer_setup("v_test").unwrap();
    assert_eq!(a.stats.art_setup_hits, 0, "empty cache cannot hit");
    assert_eq!(a.stats.art_publishes, 1, "first load must spill the blob");

    // session B — a brand-new process elastically joining the fleet —
    // warm-starts from the blob instead of re-reading init params cold
    let mut b = Session::new(Engine::cpu().unwrap(), synth_manifest(&dir), true);
    b.set_artifact_cache(Some(sweep::fleet::ArtifactCache::open(&dir).unwrap()));
    let setup_b = b.trainer_setup("v_test").unwrap();
    assert_eq!(
        b.stats.art_setup_hits, 1,
        "fresh session must warm-start from the shared blob: {:?}",
        b.stats
    );
    assert_eq!(b.stats.art_publishes, 0, "warm start must not republish");
    assert_eq!(*setup_a, *setup_b, "spill/load must round-trip the setup exactly");

    // and the warm-started trainer equals the cold one, byte for byte
    let (_engine, m) = b.engine_manifest().unwrap();
    let v = m.variant("v_test").unwrap();
    let warm = Trainer::from_setup(m, v, &setup_b, Task::Cola, cfg.clone()).unwrap();
    assert_eq!(warm.params, cold.params);
    assert_eq!(warm.param_names, cold.param_names);
    assert_eq!(warm.step_seed(), cold.step_seed());

    // the in-memory layer stacks on top: B's second call hits RAM, and
    // the disk counter does not move again
    let setup_b2 = b.trainer_setup("v_test").unwrap();
    assert!(Arc::ptr_eq(&setup_b, &setup_b2));
    assert_eq!(b.stats.setup_hits, 1);
    assert_eq!(b.stats.art_setup_hits, 1);

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn fleet_cached_dev_batches_leave_the_merged_report_untouched() {
    // Two consecutive sweeps in the same dir: pass 0 spills dev-batch
    // blobs into `cache/`, pass 1 (fresh session, fresh `prepare` — which
    // keeps `cache/`) warm-starts from them.  Both merged reports must be
    // byte-identical to the cold serial reference, and the counters must
    // show the cache actually carried the traffic — in stderr stats only,
    // never in a fragment.
    let spec = sweep::selftest_data_spec();
    let serial_dir = tmp_dir("fleet_ref");
    let serial = run_serial_cold(&serial_dir, &spec);

    let dir = tmp_dir("fleet_cache");
    for pass in 0..2u32 {
        resume::prepare(&dir, &spec, false).unwrap();
        let mut session = Session::data_only(true);
        session
            .set_artifact_cache(Some(sweep::fleet::ArtifactCache::open(&dir).unwrap()));
        sweep::run_shard(&dir, &spec, Shard::SERIAL, &mut |c, ctx| {
            run_cell(&mut session, &spec, c, ctx)
        })
        .unwrap();
        if pass == 0 {
            assert!(
                session.stats.art_publishes > 0,
                "first pass must spill dev blobs: {:?}",
                session.stats
            );
            assert_eq!(session.stats.art_dev_hits, 0, "nothing to hit yet");
        } else {
            assert!(
                session.stats.art_dev_hits > 0,
                "second pass must warm-start from the shared blobs: {:?}",
                session.stats
            );
            assert_eq!(
                session.stats.art_publishes, 0,
                "a fully warm pass republishes nothing"
            );
        }
        assert_eq!(
            report(&dir, &spec),
            serial,
            "pass {pass} with the artifact cache differs from cold serial"
        );
    }
    std::fs::remove_dir_all(&serial_dir).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn task_mismatch_is_still_rejected_through_the_warm_path() {
    let dir = tmp_dir("mismatch");
    std::fs::create_dir_all(&dir).unwrap();
    let mut session = Session::new(Engine::cpu().unwrap(), synth_manifest(&dir), true);
    let setup = session.trainer_setup("v_test").unwrap();
    let (_engine, m) = session.engine_manifest().unwrap();
    let v = m.variant("v_test").unwrap();
    // MNLI is 3-class; the variant head is 2-class
    let err = Trainer::from_setup(m, v, &setup, Task::Mnli, TrainConfig::default())
        .unwrap_err();
    assert!(format!("{err}").contains("does not match"), "{err}");
    std::fs::remove_dir_all(&dir).unwrap();
}
