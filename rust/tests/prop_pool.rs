//! Determinism / property suite for the persistent work-stealing compute
//! pool (`tensor::pool`) and every kernel dispatched through it.
//!
//! The pool's contract is that `RMM_THREADS` and `RMM_POOL_GRAIN` are
//! pure performance knobs: every pool kernel — packed matmul /
//! matmul_at / matmul_bt, the fused streamed projection for all five
//! sketch families, and the batched SORS FFT — must produce
//! **bit-identical** output for any thread count and any task grain, and
//! must agree with its serial / scalar reference.  These tests sweep
//! `RMM_THREADS ∈ {1, 2, 3, 7, 16}` through the env var itself (not a
//! private hook) to also pin the per-call re-read semantics that PR-1's
//! `OnceLock` cache broke.
//!
//! Env mutations are serialized through a file-local lock so the tests
//! stay safe under the default parallel test runner.

use std::sync::Mutex;

use rmmlinear::rmm::fft::{sors_project_cols, sors_project_fast};
use rmmlinear::rmm::sketch::{self, SketchKind};
use rmmlinear::rng::philox::PhiloxStream;
use rmmlinear::tensor::kernels::{threads, Backend, PACKED, SCALAR};
use rmmlinear::tensor::{pool, Tensor};

static ENV_LOCK: Mutex<()> = Mutex::new(());

/// Thread counts the determinism contract is swept over (unit, even, odd,
/// prime > cores, way over-subscribed).
const THREAD_COUNTS: &[usize] = &[1, 2, 3, 7, 16];

fn lock_env() -> std::sync::MutexGuard<'static, ()> {
    // A panicking test must not cascade into poisoning failures here —
    // the guarded state is the process env, which each test resets.
    ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn with_threads<T>(nt: usize, f: impl FnOnce() -> T) -> T {
    std::env::set_var("RMM_THREADS", nt.to_string());
    let r = f();
    std::env::remove_var("RMM_THREADS");
    r
}

fn randt(rows: usize, cols: usize, seed: u64) -> Tensor {
    let mut s = PhiloxStream::new(seed, 3);
    Tensor::from_fn(rows, cols, |_, _| s.next_normal())
}

/// Tolerance for packed-vs-scalar agreement, scaled to contraction depth.
fn tol(k: usize) -> f32 {
    1e-4 * (k.max(1) as f32).sqrt().max(1.0)
}

/// Adversarial GEMM shapes: unit dims, primes, dims straddling the
/// MR/NR = 8 and MC = 128 / KC = 256 block edges, a shape big enough to
/// clear the parallel threshold, and zero dims.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (7, 11, 13),
    (8, 8, 8),
    (65, 129, 127),
    (127, 259, 67),
    (256, 256, 256),
    (0, 5, 7),
    (5, 0, 7),
    (5, 7, 0),
];

/// Run `f` under every THREAD_COUNTS value and assert the outputs are
/// bit-identical to the first (serial) one.
fn sweep_bit_identical(label: &str, f: &dyn Fn() -> Tensor) {
    let reference = with_threads(THREAD_COUNTS[0], f);
    for &nt in &THREAD_COUNTS[1..] {
        let got = with_threads(nt, f);
        assert_eq!(got.data, reference.data, "{label} diverged at RMM_THREADS={nt}");
    }
}

#[test]
fn gemm_kernels_bit_identical_across_rmm_threads() {
    let _g = lock_env();
    for &(m, k, n) in SHAPES {
        let a = randt(m, k, 1);
        let b = randt(k, n, 2);
        let at = randt(k, m, 3); // (k, m) operand for Aᵀ·B
        let bt = randt(n, k, 4); // (n, k) operand for A·Bᵀ

        sweep_bit_identical(&format!("matmul ({m},{k},{n})"), &|| PACKED.matmul(&a, &b));
        sweep_bit_identical(&format!("matmul_at ({m},{k},{n})"), &|| {
            PACKED.matmul_at(&at, &b)
        });
        sweep_bit_identical(&format!("matmul_bt ({m},{k},{n})"), &|| {
            PACKED.matmul_bt(&a, &bt)
        });

        // ... and the pool path agrees with the serial Scalar reference
        if m * n > 0 {
            let scalar = SCALAR.matmul(&a, &b);
            let packed = with_threads(7, || PACKED.matmul(&a, &b));
            assert!(
                packed.max_abs_diff(&scalar) < tol(k),
                "packed vs scalar ({m},{k},{n})"
            );
            let scalar_at = SCALAR.matmul_at(&at, &b);
            let packed_at = with_threads(7, || PACKED.matmul_at(&at, &b));
            assert!(
                packed_at.max_abs_diff(&scalar_at) < tol(k),
                "packed_at vs scalar ({m},{k},{n})"
            );
            let scalar_bt = SCALAR.matmul_bt(&a, &bt);
            let packed_bt = with_threads(7, || PACKED.matmul_bt(&a, &bt));
            assert!(
                packed_bt.max_abs_diff(&scalar_bt) < tol(k),
                "packed_bt vs scalar ({m},{k},{n})"
            );
        }
    }
}

#[test]
fn fused_projection_bit_identical_across_rmm_threads() {
    let _g = lock_env();
    // (b, n, b_proj): tile edges, b_proj > b, and one shape past the
    // parallel work threshold (300·80·50 = 1.2e6 madds).
    for &(b, n, bp) in &[(5usize, 3usize, 2usize), (64, 16, 64), (129, 9, 65), (300, 50, 80)] {
        let x = randt(b, n, 7);
        for kind in SketchKind::ALL {
            let reference = with_threads(THREAD_COUNTS[0], || {
                sketch::project_streamed(kind, &x, bp, (3, 4))
            });
            for &nt in &THREAD_COUNTS[1..] {
                let got =
                    with_threads(nt, || sketch::project_streamed(kind, &x, bp, (3, 4)));
                assert_eq!(
                    got.data, reference.data,
                    "{kind:?} ({b},{n},{bp}) diverged at RMM_THREADS={nt}"
                );
            }
            // scalar-backend dense algebra agreement (approximate: the
            // dense path sums in a different order)
            let s = sketch::sketch(kind, b, bp, (3, 4));
            let dense = SCALAR.matmul_at(&s, &x);
            assert!(
                reference.max_abs_diff(&dense) < tol(b) * 10.0,
                "{kind:?} ({b},{n},{bp}) fused vs dense"
            );
        }
    }
}

#[test]
fn batched_sors_bit_identical_across_rmm_threads_and_equals_cols() {
    let _g = lock_env();
    // (b, n, b_proj): small serial shape and one past the parallel
    // threshold (256·100·8 = 2.05e5 work units).
    for &(b, n, bp) in &[(32usize, 7usize, 12usize), (256, 100, 64)] {
        let x = randt(b, n, 11);
        for use_dct in [true, false] {
            // the column-by-column path is fully serial: the exactness
            // reference for every thread count
            let cols = sors_project_cols(use_dct, &x, bp, (5, 6));
            for &nt in THREAD_COUNTS {
                let got = with_threads(nt, || sors_project_fast(use_dct, &x, bp, (5, 6)));
                assert_eq!(
                    got.data, cols.data,
                    "sors dct={use_dct} ({b},{n},{bp}) diverged at RMM_THREADS={nt}"
                );
            }
        }
    }
}

#[test]
fn scratch_arena_reuse_is_bit_identical() {
    let _g = lock_env();
    // The worker-local A-panel arena is reused across tasks, runs and
    // shapes; a dirty arena (stale floats from a bigger earlier GEMM)
    // must be invisible.  Interleave shapes so every later call sees an
    // arena dirtied by a *different* (m, k) geometry, and sweep thread
    // counts and grains so the arena is exercised on workers and on the
    // caller (nt=1 inline path) alike.
    let big = (randt(200, 500, 41), randt(500, 160, 42)); // dirties ~MC·KC
    let small = (randt(9, 17, 43), randt(17, 33, 44)); // sub-threshold, inline
    let mid = (randt(130, 300, 45), randt(300, 140, 46));
    let reference = with_threads(1, || {
        (
            PACKED.matmul(&big.0, &big.1),
            PACKED.matmul(&small.0, &small.1),
            PACKED.matmul(&mid.0, &mid.1),
        )
    });
    for &nt in THREAD_COUNTS {
        for grain in ["1", "8", "64"] {
            std::env::set_var("RMM_POOL_GRAIN", grain);
            let got = with_threads(nt, || {
                // big → small → mid → small: each call after the first
                // runs on an arena sized/dirtied by its predecessor
                let b = PACKED.matmul(&big.0, &big.1);
                let s1 = PACKED.matmul(&small.0, &small.1);
                let m = PACKED.matmul(&mid.0, &mid.1);
                let s2 = PACKED.matmul(&small.0, &small.1);
                assert_eq!(
                    s1.data, s2.data,
                    "same GEMM diverged on a dirtier arena (nt={nt} grain={grain})"
                );
                (b, s1, m)
            });
            assert_eq!(got.0.data, reference.0.data, "big nt={nt} grain={grain}");
            assert_eq!(got.1.data, reference.1.data, "small nt={nt} grain={grain}");
            assert_eq!(got.2.data, reference.2.data, "mid nt={nt} grain={grain}");
        }
    }
    std::env::remove_var("RMM_POOL_GRAIN");
}

#[test]
fn task_grain_never_changes_results() {
    let _g = lock_env();
    std::env::set_var("RMM_THREADS", "3");
    let (m, k, n) = (130usize, 300usize, 140usize);
    let a = randt(m, k, 21);
    let b = randt(k, n, 22);
    let x = randt(300, 50, 23);
    let reference = (
        PACKED.matmul(&a, &b),
        sketch::project_streamed(SketchKind::Gauss, &x, 80, (3, 4)),
    );
    for grain in ["1", "8", "64", "4096"] {
        std::env::set_var("RMM_POOL_GRAIN", grain);
        let c = PACKED.matmul(&a, &b);
        let p = sketch::project_streamed(SketchKind::Gauss, &x, 80, (3, 4));
        assert_eq!(c.data, reference.0.data, "gemm diverged at grain {grain}");
        assert_eq!(p.data, reference.1.data, "projection diverged at grain {grain}");
    }
    std::env::remove_var("RMM_POOL_GRAIN");
    std::env::remove_var("RMM_THREADS");
}

#[test]
fn rmm_threads_env_is_read_per_call() {
    // Regression for the PR-1 OnceLock cache: later env changes must be
    // visible.  (This is exactly what lets the sweeps above work at all.)
    let _g = lock_env();
    std::env::set_var("RMM_THREADS", "2");
    assert_eq!(threads::num_threads(), 2);
    std::env::set_var("RMM_THREADS", "5");
    assert_eq!(threads::num_threads(), 5, "RMM_THREADS change was ignored (stale cache)");
    std::env::set_var("RMM_THREADS", "not-a-number");
    assert!(threads::num_threads() >= 1, "garbage env must fall back, not panic");
    std::env::remove_var("RMM_THREADS");
    assert!(threads::num_threads() >= 1);
}

#[test]
fn pool_survives_task_panics_and_keeps_counting() {
    let _g = lock_env();
    std::env::set_var("RMM_THREADS", "4");
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pool::global().run(4, 8, |i| {
            if i == 5 {
                panic!("injected task panic");
            }
        });
    }));
    assert!(r.is_err(), "task panic must propagate to the caller");

    // the pool must keep working afterwards, and its counters advance
    let before = pool::stats();
    let (m, k, n) = (160usize, 200usize, 180usize); // > PAR_FLOP_THRESHOLD
    let a = randt(m, k, 31);
    let b = randt(k, n, 32);
    let got = PACKED.matmul(&a, &b);
    let scalar = SCALAR.matmul(&a, &b);
    assert!(got.max_abs_diff(&scalar) < tol(k));
    let d = pool::stats().delta_since(before);
    assert!(d.runs >= 1 && d.tasks >= 1, "pool counters must advance: {d:?}");
    std::env::remove_var("RMM_THREADS");
}
