//! Property tests pinning the `Packed` kernel backend against the naive
//! triple loop on adversarial shapes, and the fused streamed sketch
//! projection against the dense `SᵀX` algebra for every `SketchKind` —
//! including exact (bit-level) agreement with the seed crate's streaming
//! accumulation order.

use rmmlinear::rmm::sketch::{self, SketchKind};
use rmmlinear::rng::philox::{
    element_normal, element_rademacher, PhiloxStream, STREAM_SKETCH,
};
use rmmlinear::tensor::kernels::{Backend, PACKED, SCALAR};
use rmmlinear::tensor::Tensor;
use rmmlinear::util::prop::prop_check;

fn randt(rows: usize, cols: usize, seed: u64) -> Tensor {
    let mut s = PhiloxStream::new(seed, 3);
    Tensor::from_fn(rows, cols, |_, _| s.next_normal())
}

/// f64-accumulated reference C = A · B.
fn naive(a: &Tensor, b: &Tensor) -> Tensor {
    let mut c = Tensor::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for j in 0..b.cols {
            let mut acc = 0.0f64;
            for k in 0..a.cols {
                acc += (a.at(i, k) as f64) * (b.at(k, j) as f64);
            }
            *c.at_mut(i, j) = acc as f32;
        }
    }
    c
}

/// Tolerance scaled to the contraction depth (f32 accumulation noise).
fn tol(k: usize) -> f32 {
    1e-4 * (k.max(1) as f32).sqrt().max(1.0)
}

/// Adversarial fixed shapes: unit dims, primes, dims straddling every
/// block boundary (MR/NR = 8, MC = 128, KC = 256, NC = 1024), zero dims.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 257, 1),
    (2, 3, 5),
    (7, 11, 13),
    (8, 8, 8),
    (9, 17, 31),
    (64, 64, 64),
    (65, 129, 127),
    (127, 259, 67),
    (130, 300, 140),
    (300, 129, 1030),
    (0, 5, 7),
    (5, 0, 7),
    (5, 7, 0),
];

#[test]
fn packed_matmul_matches_naive_on_adversarial_shapes() {
    for &(m, k, n) in SHAPES {
        let a = randt(m, k, 1);
        let b = randt(k, n, 2);
        let want = naive(&a, &b);
        let got = PACKED.matmul(&a, &b);
        assert_eq!((got.rows, got.cols), (m, n));
        if m * n > 0 {
            assert!(got.max_abs_diff(&want) < tol(k), "packed ({m},{k},{n})");
        }
        let got_s = SCALAR.matmul(&a, &b);
        if m * n > 0 {
            assert!(got_s.max_abs_diff(&want) < tol(k), "scalar ({m},{k},{n})");
        }
    }
}

#[test]
fn packed_transpose_variants_match_naive_on_adversarial_shapes() {
    for &(m, k, n) in SHAPES {
        // Aᵀ·B with A stored (k, m)
        let a = randt(k, m, 3);
        let b = randt(k, n, 4);
        let want = naive(&a.transpose(), &b);
        let got = PACKED.matmul_at(&a, &b);
        if m * n > 0 {
            assert!(got.max_abs_diff(&want) < tol(k), "at ({m},{k},{n})");
        }

        // A·Bᵀ with B stored (n, k)
        let a2 = randt(m, k, 5);
        let b2 = randt(n, k, 6);
        let want2 = naive(&a2, &b2.transpose());
        let got2 = PACKED.matmul_bt(&a2, &b2);
        if m * n > 0 {
            assert!(got2.max_abs_diff(&want2) < tol(k), "bt ({m},{k},{n})");
        }
    }
}

#[test]
fn prop_packed_equals_scalar_on_random_shapes() {
    prop_check("packed == scalar (random shapes)", 60, |g| {
        let m = g.usize_in(1, 40);
        let k = g.usize_in(1, 40);
        let n = g.usize_in(1, 40);
        let a = g.tensor(m..=m, k..=k);
        let b = g.tensor(k..=k, n..=n);
        let p = PACKED.matmul(&a, &b);
        let s = SCALAR.matmul(&a, &b);
        assert!(p.max_abs_diff(&s) < tol(k), "({m},{k},{n})");
    });
}

/// The seed crate's streaming loop (i outer, j inner) for the RNG
/// families — the bit-compat reference for the fused tiled path.
fn seed_streamed(kind: SketchKind, x: &Tensor, b_proj: usize, seed: (u32, u32)) -> Tensor {
    let (b, n) = (x.rows, x.cols);
    let inv = 1.0 / (b_proj as f32).sqrt();
    let mut out = Tensor::zeros(b_proj, n);
    for i in 0..b {
        let xrow = x.row(i);
        for j in 0..b_proj {
            let s = match kind {
                SketchKind::Gauss => {
                    element_normal(i as u32, j as u32, seed, STREAM_SKETCH) * inv
                }
                SketchKind::Rademacher => {
                    element_rademacher(i as u32, j as u32, seed, STREAM_SKETCH) * inv
                }
                _ => unreachable!(),
            };
            let orow = &mut out.data[j * n..(j + 1) * n];
            for c in 0..n {
                orow[c] += s * xrow[c];
            }
        }
    }
    out
}

/// Dense reference with the same per-element accumulation order the fused
/// structured path uses (ascending input row), computed from the dense S.
fn dense_ordered(s: &Tensor, x: &Tensor) -> Tensor {
    let (b, b_proj) = (s.rows, s.cols);
    let n = x.cols;
    let mut out = Tensor::zeros(b_proj, n);
    for i in 0..b {
        let xrow = x.row(i);
        for j in 0..b_proj {
            let sv = s.at(i, j);
            let orow = &mut out.data[j * n..(j + 1) * n];
            for c in 0..n {
                orow[c] += sv * xrow[c];
            }
        }
    }
    out
}

#[test]
fn fused_rng_projection_is_bit_identical_to_seed_stream() {
    // shapes straddling the 64×64 S-tile and the thread-band split; the
    // last one is big enough to take the multithreaded path
    for &(b, n, bp) in
        &[(5usize, 3usize, 2usize), (64, 16, 64), (129, 9, 65), (200, 4, 130), (300, 50, 80)]
    {
        let x = randt(b, n, 7);
        for kind in [SketchKind::Gauss, SketchKind::Rademacher] {
            let want = seed_streamed(kind, &x, bp, (3, 4));
            let got = sketch::project_streamed(kind, &x, bp, (3, 4));
            assert_eq!(want.data, got.data, "{kind:?} ({b},{n},{bp})");
        }
    }
}

#[test]
fn fused_projection_matches_dense_sketch_for_all_kinds() {
    prop_check("fused project == dense SᵀX (all kinds)", 25, |g| {
        let b = g.usize_in(1, 70);
        let n = g.usize_in(1, 12);
        let bp = g.usize_in(1, 70);
        let seed = g.seed_pair();
        let x = g.tensor(b..=b, n..=n);
        for kind in SketchKind::ALL {
            // Every family shares entry formulas and ascending-row
            // accumulation order with the dense construction, so the
            // agreement is exact, not approximate.
            let s = sketch::sketch(kind, b, bp, seed);
            let want = dense_ordered(&s, &x);
            let got = sketch::project_streamed(kind, &x, bp, seed);
            assert_eq!(want.data, got.data, "{kind:?} ({b},{n},{bp})");
        }
    });
}

#[test]
fn fused_projection_never_needs_huge_b_proj_edgecases() {
    // b_proj ≫ b and b ≫ b_proj, both across the tile boundary
    for &(b, bp) in &[(3usize, 300usize), (300, 3), (1, 1), (65, 1), (1, 65)] {
        let x = randt(b, 5, 9);
        for kind in SketchKind::ALL {
            let s = sketch::sketch(kind, b, bp, (1, 2));
            let want = dense_ordered(&s, &x);
            let got = sketch::project_streamed(kind, &x, bp, (1, 2));
            assert_eq!(want.data, got.data, "{kind:?} ({b},{bp})");
        }
    }
}

// ---- forced-dispatch matrix: bit-identity across SIMD levels ----

use rmmlinear::rmm::fft;
use rmmlinear::tensor::kernels::dispatch::{self, SimdLevel};
use rmmlinear::tensor::pool;

/// Every kernel surface once: all three GEMM orientations over the
/// adversarial shape list (MR/NR remainders, zero dims), all six fused
/// projection families, and the batched SORS fast path.  Returns raw
/// `data` vectors so callers can compare bit patterns.
fn kernel_surfaces() -> Vec<Vec<f32>> {
    let mut outs: Vec<Vec<f32>> = Vec::new();
    for &(m, k, n) in SHAPES {
        let a = randt(m, k, 1);
        let b = randt(k, n, 2);
        let at = randt(k, m, 3);
        let bt = randt(n, k, 6);
        outs.push(PACKED.matmul(&a, &b).data);
        outs.push(PACKED.matmul_at(&at, &b).data);
        outs.push(PACKED.matmul_bt(&a, &bt).data);
    }
    let x = randt(70, 9, 7);
    for kind in SketchKind::ALL {
        outs.push(sketch::project_streamed(kind, &x, 19, (3, 4)).data);
    }
    let xs = randt(64, 10, 8); // SORS needs power-of-two batch rows
    outs.push(fft::sors_project_fast(true, &xs, 24, (5, 6)).data);
    outs.push(fft::sors_project_fast(false, &xs, 24, (5, 6)).data);
    outs
}

#[test]
fn forced_dispatch_levels_are_bit_identical_in_process() {
    let _g = pool::knob_test_lock();
    // Reference: everything forced through the scalar per-element loop.
    dispatch::set_simd_override(Some(SimdLevel::Scalar)).unwrap();
    let want = kernel_surfaces();
    for level in dispatch::supported_levels() {
        dispatch::set_simd_override(Some(level)).unwrap();
        let got = kernel_surfaces();
        assert_eq!(want.len(), got.len());
        for (i, (w, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(
                w.iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
                g.iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
                "surface {i} differs between scalar and {}",
                level.name()
            );
        }
    }
    dispatch::set_simd_override(None).unwrap();
}

/// The cross-process half of the matrix: `repro kernel-digest` under
/// every supported `RMM_SIMD` × `RMM_THREADS` ∈ {1, 4} must print
/// byte-identical digest output (each forced level resolves through the
/// env layer in a fresh process, exactly how a user forces one).
#[test]
fn kernel_digest_is_byte_identical_across_simd_levels_and_threads() {
    let exe = env!("CARGO_BIN_EXE_repro");
    let mut reference: Option<(String, String)> = None;
    for level in SimdLevel::ALL {
        if !level.supported() {
            eprintln!("skipping RMM_SIMD={} (unsupported on this CPU)", level.name());
            continue;
        }
        for threads in ["1", "4"] {
            let tag = format!("RMM_SIMD={} RMM_THREADS={threads}", level.name());
            let out = std::process::Command::new(exe)
                .arg("kernel-digest")
                .env("RMM_SIMD", level.name())
                .env("RMM_THREADS", threads)
                .output()
                .expect("spawning repro kernel-digest");
            assert!(
                out.status.success(),
                "kernel-digest failed under {tag}: {}",
                String::from_utf8_lossy(&out.stderr)
            );
            let text = String::from_utf8(out.stdout).expect("digest output is UTF-8");
            assert!(text.contains("project[wtacrs]"), "digest output truncated:\n{text}");
            match &reference {
                None => reference = Some((tag, text)),
                Some((rtag, rtext)) => {
                    assert_eq!(rtext, &text, "digests diverge: {rtag} vs {tag}")
                }
            }
        }
    }
    assert!(reference.is_some(), "scalar and portable are always supported");
}

#[test]
fn malformed_rmm_simd_is_rejected_by_the_cli() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .arg("kernel-digest")
        .env("RMM_SIMD", "sse9")
        .output()
        .expect("spawning repro kernel-digest");
    assert!(!out.status.success(), "garbage RMM_SIMD must fail loudly, not fall back");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(
        err.contains("RMM_SIMD") && err.contains("'sse9'"),
        "error must name the knob, the offending value and the domain: {err}"
    );
}
