//! Property tests for the data substrate: generators, batcher, metrics.

use rmmlinear::data::{Batch, Batcher, MetricAccum, Split, Task, TaskGen, Tokenizer};
use rmmlinear::util::prop::prop_check;

#[test]
fn examples_deterministic_across_generators() {
    prop_check("generator determinism", 50, |g| {
        let seed = g.usize_in(0, 10_000) as u64;
        let idx = g.usize_in(0, 500);
        let task = Task::ALL[g.usize_in(0, Task::ALL.len() - 1)];
        let tok = Tokenizer::new(256);
        let g1 = TaskGen::new(task, &tok, 32, seed);
        let g2 = TaskGen::new(task, &tok, 32, seed);
        let a = g1.example(Split::Train, idx);
        let b = g2.example(Split::Train, idx);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.label, b.label);
    });
}

#[test]
fn tokens_always_within_vocab_and_seq_len() {
    prop_check("token ranges", 60, |g| {
        let vocab = g.usize_in(32, 512);
        let seq = g.usize_in(12, 64);
        let task = Task::ALL[g.usize_in(0, Task::ALL.len() - 1)];
        let tok = Tokenizer::new(vocab);
        let gen = TaskGen::new(task, &tok, seq, g.usize_in(0, 1000) as u64);
        let ex = gen.example(Split::Dev, g.usize_in(0, 100));
        assert!(ex.tokens.len() <= seq);
        assert!(!ex.tokens.is_empty());
        assert!(ex.tokens.iter().all(|&t| (t as usize) < vocab));
    });
}

#[test]
fn batcher_covers_each_split_exactly_once() {
    prop_check("batcher coverage", 40, |g| {
        let task = Task::ALL[g.usize_in(0, Task::ALL.len() - 1)];
        let bsz = g.usize_in(1, 64);
        let tok = Tokenizer::new(256);
        let gen = TaskGen::new(task, &tok, 16, 7);
        let split = if g.bool() { Split::Train } else { Split::Dev };
        let b = Batcher::new(&gen, split, bsz, g.usize_in(0, 5) as u64);
        let n = b.n_examples();
        let n_batches = b.n_batches();
        let total: usize = b.map(|batch| batch.valid).sum();
        assert_eq!(total, n);
        assert_eq!(n_batches, n.div_ceil(bsz));
    });
}

/// Exhaustive wrap-around edge cases: for each split size `n`, batch
/// sizes with `n % bsz ∈ {0, 1, bsz-1}` (plus bsz=1 and bsz=n) must
/// produce the right batch count, the right per-batch `valid`, and
/// wrapped rows that are literal copies of the epoch's leading examples
/// — the contract the evaluator's metric weighting stands on.
#[test]
fn wraparound_valid_counts_exhaustive() {
    let tok = Tokenizer::new(256);
    for task in [Task::Wnli, Task::Rte, Task::Cola] {
        for split in [Split::Train, Split::Dev] {
            let gen = TaskGen::new(task, &tok, 16, 11);
            let n = task.split_size(split);
            // n % bsz == 0 (divisor + the full-split batch), == 1,
            // == bsz - 1 (single wrapping batch), and the degenerate 1.
            let cases = [1usize, 2, n, n - 1, n + 1];
            for bsz in cases {
                assert!(
                    n % 2 == 0 || bsz != 2,
                    "pick split sizes with an even count for the rem-0 case"
                );
                let batches: Vec<Batch> = Batcher::new(&gen, split, bsz, 3).collect();
                assert_eq!(batches.len(), n.div_ceil(bsz), "task={task:?} bsz={bsz}");
                let total: usize = batches.iter().map(|b| b.valid).sum();
                assert_eq!(total, n, "task={task:?} bsz={bsz}");
                for (i, b) in batches.iter().enumerate() {
                    let expected = if (i + 1) * bsz <= n { bsz } else { n - i * bsz };
                    assert_eq!(b.valid, expected, "task={task:?} bsz={bsz} batch={i}");
                    assert_eq!(b.tokens.len(), bsz * 16);
                    assert_eq!(b.mask.len(), bsz * 16);
                    assert_eq!(b.labels_i.len(), bsz);
                }
                // wrapped rows of the final batch duplicate the epoch's
                // leading examples in order
                let last = batches.last().unwrap();
                if last.valid < bsz {
                    let first = &batches[0];
                    for wrapped in last.valid..bsz {
                        let src = wrapped - last.valid;
                        if src >= first.valid.min(bsz) {
                            break; // wrapped past the first batch (tiny n)
                        }
                        assert_eq!(
                            last.tokens[wrapped * 16..(wrapped + 1) * 16],
                            first.tokens[src * 16..(src + 1) * 16],
                            "task={task:?} bsz={bsz} wrapped row {wrapped}"
                        );
                        assert_eq!(last.labels_i[wrapped], first.labels_i[src]);
                    }
                }
            }
        }
    }
}

/// Wrapped (padding) rows must never reach a metric: scoring only the
/// `valid` prefix must give the same result no matter what logits the
/// wrapped rows hold.
#[test]
fn wrapped_rows_never_contribute_to_metrics() {
    for task in [Task::Qnli, Task::Cola, Task::Mrpc] {
        // 3 valid rows + 2 wrapped rows with adversarial logits/labels
        let clean = [0.1f32, 0.9, 0.8, 0.2, 0.0, 1.0];
        let mut with_garbage = clean.to_vec();
        with_garbage.extend([100.0, -100.0, -100.0, 100.0]); // wrapped rows
        let labels = [1i32, 0, 1, 0, 0];

        let mut a = MetricAccum::new();
        a.add_logits(task, &clean, 2, &labels[..3], &[], 3);
        let mut b = MetricAccum::new();
        b.add_logits(task, &with_garbage, 2, &labels, &[], 3);
        assert_eq!(a.count(), 3);
        assert_eq!(b.count(), 3);
        let (sa, sb) = (a.score(task), b.score(task));
        assert!(
            (sa - sb).abs() < 1e-12,
            "{task:?}: wrapped rows leaked into the metric ({sa} vs {sb})"
        );
    }
}

fn valence_sum(ex: &rmmlinear::data::Example) -> f64 {
    // word valence: +1 for even lexicon ids, −1 for odd (FIRST_WORD = 4)
    ex.tokens
        .iter()
        .filter(|&&t| t >= 4)
        .map(|&t| if (t - 4) % 2 == 0 { 1.0 } else { -1.0 })
        .sum()
}

#[test]
fn labels_learnable_signal_exists() {
    // The latent rules must be learnable: the pooled-valence heuristic
    // (exactly the feature a bag-of-words encoder can compute) must beat
    // chance by a clear margin on the clean tasks and by less on the noisy
    // ones (the Table-2 difficulty ordering).
    let tok = Tokenizer::new(256);
    let mut accs = std::collections::HashMap::new();
    for task in [Task::Sst2, Task::Qnli, Task::Cola, Task::Rte, Task::Wnli] {
        let gen = TaskGen::new(task, &tok, 32, 3);
        let n = 600;
        let mut correct = 0;
        for i in 0..n {
            let ex = gen.example(Split::Train, i);
            let thr = if task == Task::Mrpc { 1.0 } else { 0.0 };
            let pred = if valence_sum(&ex) > thr { 1.0 } else { 0.0 };
            if pred == ex.label {
                correct += 1;
            }
        }
        accs.insert(task, correct as f64 / n as f64);
    }
    assert!(accs[&Task::Sst2] > 0.9, "{accs:?}");
    assert!(accs[&Task::Qnli] > 0.8, "{accs:?}");
    assert!(accs[&Task::Cola] > 0.75, "{accs:?}");
    assert!(accs[&Task::Rte] > 0.65, "{accs:?}");
    // WNLI's 35% flip rate caps achievable accuracy near 0.65
    assert!(accs[&Task::Wnli] > 0.5 && accs[&Task::Wnli] < 0.75, "{accs:?}");
    // difficulty ordering (Table 2's degradation driver)
    assert!(accs[&Task::Sst2] > accs[&Task::Cola]);
    assert!(accs[&Task::Cola] > accs[&Task::Wnli]);
}

#[test]
fn nli_buckets_follow_valence() {
    let tok = Tokenizer::new(256);
    let gen = TaskGen::new(Task::Mnli, &tok, 32, 5);
    let mut correct = 0;
    let n = 600;
    for i in 0..n {
        let ex = gen.example(Split::Train, i);
        let s = valence_sum(&ex);
        let pred = if s >= 3.0 {
            0.0
        } else if s <= -3.0 {
            2.0
        } else {
            1.0
        };
        if pred == ex.label {
            correct += 1;
        }
    }
    let acc = correct as f64 / n as f64;
    assert!(acc > 0.85, "bucket heuristic acc {acc}");
}

#[test]
fn regression_scores_correlate_with_valence() {
    let tok = Tokenizer::new(256);
    let gen = TaskGen::new(Task::Stsb, &tok, 32, 5);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for i in 0..300 {
        let ex = gen.example(Split::Train, i);
        xs.push(valence_sum(&ex) / ex.tokens.len() as f64);
        ys.push(ex.label as f64);
    }
    let r = rmmlinear::util::stats::pearson(&xs, &ys);
    assert!(r > 0.8, "valence-score correlation too weak: {r}");
}
