//! Race / crash / determinism suite for the dynamic work-stealing cell
//! scheduler (`sweep::claim` + `sweep::scheduler`).
//!
//! The contract under test (see `sweep/mod.rs` for the canonical prose):
//!
//! * **Exactly one winner** — however many claimants race a cell, the
//!   create-exclusive claim protocol admits exactly one.
//! * **Crash healing** — a worker killed mid-lease leaves a claim that
//!   goes stale after the TTL; surviving workers reclaim and finish, and
//!   the merged report is *still* byte-identical to the serial run.
//! * **Schedule invisibility** — dynamic sweeps merge byte-identically
//!   to the serial run for worker counts {1, 2, 3, 7}, in-process and
//!   through real `repro sweep-worker` subprocesses.
//! * **No idle workers** — on a skewed-cost grid (the MNLI-vs-WNLI
//!   shape that motivates dynamic scheduling), every worker completes
//!   at least one cell and the grid is covered exactly once: fast
//!   workers steal the queue the slow cell would have stranded.
//! * **Failure diagnostics** — a failing worker process surfaces its
//!   exit status and a stderr tail, not a bare error.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Barrier;

use rmmlinear::config::TrainConfig;
use rmmlinear::sweep::{
    self,
    claim::{self, ClaimAttempt},
    fleet, merge, resume, DynamicConfig, Shard, SweepSpec,
};
use rmmlinear::util::json::Json;
use rmmlinear::util::prop::prop_check;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("rmm_prop_sched_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A mock grid exercising every cell axis (same shape as prop_sweep's).
fn mock_spec(n_tasks: usize, n_rhos: usize, n_seeds: usize) -> SweepSpec {
    let mut spec = SweepSpec::new("mock", TrainConfig::default());
    for r in 0..n_rhos {
        for t in 0..n_tasks {
            for s in 0..n_seeds {
                spec.push(
                    format!("v{t}_r{r}"),
                    format!("task{t}"),
                    1.0 / (r + 1) as f64,
                    if t % 2 == 0 { "gauss" } else { "dct" },
                    s as u64,
                    t * 8,
                );
            }
        }
    }
    spec
}

fn report(dir: &Path, spec: &SweepSpec) -> String {
    Json::Arr(merge::merge(dir, spec).expect("sweep incomplete")).to_string_pretty()
}

fn run_serial(dir: &Path, spec: &SweepSpec) -> String {
    resume::prepare(dir, spec, false).unwrap();
    sweep::run_shard(dir, spec, Shard::SERIAL, &mut |c, _| Ok(sweep::mock_cell(c)))
        .unwrap();
    report(dir, spec)
}

/// Run `workers` in-process dynamic workers to completion and return
/// each worker's completed-cell list.
fn run_dynamic_workers(dir: &Path, spec: &SweepSpec, workers: usize) -> Vec<Vec<usize>> {
    run_dynamic_workers_with_cost(dir, spec, workers, |_| 0)
}

/// Same, with a per-cell synthetic cost in ms (the skew knob).  All
/// workers rendezvous on a barrier before their first claim, so a
/// slowly-spawned thread can never find the grid already drained — the
/// no-idle-worker assertion measures scheduling, not spawn jitter.
fn run_dynamic_workers_with_cost(
    dir: &Path,
    spec: &SweepSpec,
    workers: usize,
    cost_ms: fn(usize) -> u64,
) -> Vec<Vec<usize>> {
    let start = Barrier::new(workers);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let start = &start;
                s.spawn(move || {
                    let cfg = DynamicConfig::new(&format!("w{w}"), 60_000);
                    start.wait();
                    sweep::run_dynamic(dir, spec, &cfg, &mut |c, _| {
                        let ms = cost_ms(c.index);
                        if ms > 0 {
                            std::thread::sleep(std::time::Duration::from_millis(ms));
                        }
                        Ok(sweep::mock_cell(c))
                    })
                    .expect("dynamic worker failed")
                    .ran
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Flattened, sorted union of per-worker completion lists.
fn cover(ran: &[Vec<usize>]) -> Vec<usize> {
    let mut all: Vec<usize> = ran.iter().flatten().copied().collect();
    all.sort_unstable();
    all
}

// ---------------------------------------------------------------------------
// Claim races
// ---------------------------------------------------------------------------

#[test]
fn concurrent_claimants_have_exactly_one_winner() {
    prop_check("exactly one claim winner", 8, |g| {
        let claimants = g.usize_in(2, 8);
        let cell = g.usize_in(0, 40);
        let dir = tmp_dir(&format!("one_winner_{}", g.case_seed));
        std::fs::create_dir_all(&dir).unwrap();
        let wins = AtomicUsize::new(0);
        let barrier = Barrier::new(claimants);
        std::thread::scope(|s| {
            for t in 0..claimants {
                let (dir, wins, barrier) = (&dir, &wins, &barrier);
                s.spawn(move || {
                    let w = claim::worker_id(&format!("claimant{t}"));
                    barrier.wait(); // release all claimants at once
                    match claim::try_claim(dir, cell, &w, 60_000).unwrap() {
                        ClaimAttempt::Won(guard) => {
                            wins.fetch_add(1, Ordering::SeqCst);
                            // hold the claim through the race: losers
                            // must see Held, not a second create win
                            std::mem::forget(guard);
                        }
                        ClaimAttempt::Held => {}
                    }
                });
            }
        });
        assert_eq!(
            wins.load(Ordering::SeqCst),
            1,
            "{claimants} claimants on cell {cell}: exactly one must win"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    });
}

#[test]
fn concurrent_reclaim_of_stale_lease_admits_a_winner_and_keeps_the_cell_claimed() {
    // All claimants race the same *stale* claim.  Strict exactly-one is
    // only an O_EXCL-layer guarantee; across a steal, the verify-after-
    // capture guard makes one winner overwhelmingly likely but a ≥3-party
    // microsecond interleaving can still admit a duplicate — the
    // documented benign reclaim corner (duplicates commit identical
    // fragments).  The hard properties merge correctness rests on, and
    // which this test pins: the cell is never *lost* (>= 1 winner) and
    // it ends the race claimed by a live thief, with the dead worker's
    // lease gone.  Note the sleep: an ancient *embedded* heartbeat alone
    // no longer makes a claim stale (it could be a slow writer's clock —
    // the symmetric skew rule takes min(heartbeat age, mtime age)), so
    // the file's mtime must genuinely age past the TTL first.
    let dir = tmp_dir("stale_race");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        claim::claim_path(&dir, 5),
        r#"{"heartbeat_ms": 1, "worker": "dead-worker"}"#,
    )
    .unwrap();
    std::thread::sleep(std::time::Duration::from_millis(80));
    let wins = AtomicUsize::new(0);
    let barrier = Barrier::new(6);
    std::thread::scope(|s| {
        for t in 0..6 {
            let (dir, wins, barrier) = (&dir, &wins, &barrier);
            s.spawn(move || {
                let w = claim::worker_id(&format!("thief{t}"));
                barrier.wait();
                if let ClaimAttempt::Won(g) =
                    claim::try_claim(dir, 5, &w, 50).unwrap()
                {
                    wins.fetch_add(1, Ordering::SeqCst);
                    std::mem::forget(g); // hold the lease through the race
                }
            });
        }
    });
    let wins = wins.load(Ordering::SeqCst);
    assert!(wins >= 1, "stale reclaim must never lose the cell");
    let owner = claim::read_claim(&dir, 5).expect("cell must end the race claimed");
    assert!(
        owner.worker.starts_with("thief"),
        "dead worker's lease must be gone, got {owner:?} (wins={wins})"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// Crash healing: kill a worker mid-lease, reclaim, finish
// ---------------------------------------------------------------------------

#[test]
fn stale_lease_from_dead_worker_is_reclaimed_and_sweep_finishes() {
    let spec = mock_spec(3, 2, 1); // 6 cells
    let serial_dir = tmp_dir("reclaim_ref");
    let serial = run_serial(&serial_dir, &spec);

    let dir = tmp_dir("reclaim");
    resume::prepare(&dir, &spec, false).unwrap();
    let cdir = resume::cells_dir(&dir);
    // a worker died holding cells 1 and 4: ancient heartbeats, no fragments
    for i in [1usize, 4] {
        std::fs::write(
            claim::claim_path(&cdir, i),
            r#"{"heartbeat_ms": 1, "worker": "killed-mid-lease"}"#,
        )
        .unwrap();
    }
    let cfg = DynamicConfig::new("survivor", 500);
    let run = sweep::run_dynamic(&dir, &spec, &cfg, &mut |c, _| Ok(sweep::mock_cell(c)))
        .unwrap();
    assert_eq!(run.ran.len(), spec.cells.len(), "survivor must run every cell");
    assert_eq!(report(&dir, &spec), serial, "healed sweep must match serial bytes");
    for i in [1usize, 4] {
        assert!(!claim::claim_path(&cdir, i).exists(), "stale claim {i} must be gone");
    }
    std::fs::remove_dir_all(&serial_dir).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn killed_worker_subprocess_is_healed_by_a_second_worker() {
    let spec = mock_spec(3, 2, 1); // 6 cells
    let serial_dir = tmp_dir("kill_ref");
    let serial = run_serial(&serial_dir, &spec);

    let dir = tmp_dir("kill");
    resume::prepare(&dir, &spec, false).unwrap();
    // worker A: slow mock cells (300 ms each) so the kill lands mid-lease
    let mut a = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["sweep-worker", "--dir"])
        .arg(&dir)
        .args(["--schedule", "dynamic", "--mock-cell-ms", "300", "--lease-ttl-ms", "60000"])
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawning slow worker");
    std::thread::sleep(std::time::Duration::from_millis(150));
    a.kill().expect("killing worker mid-lease");
    a.wait().unwrap();

    // worker B: fast cells, short TTL — must wait out A's lease (if A got
    // that far), reclaim, and finish the whole grid
    let status = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["sweep-worker", "--dir"])
        .arg(&dir)
        .args(["--schedule", "dynamic", "--lease-ttl-ms", "400"])
        .status()
        .expect("spawning healing worker");
    assert!(status.success(), "healing worker exited {status}");
    assert_eq!(report(&dir, &spec), serial, "healed sweep differs from serial");
    std::fs::remove_dir_all(&serial_dir).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// Byte-identity vs serial across worker counts
// ---------------------------------------------------------------------------

#[test]
fn dynamic_workers_match_serial_byte_for_byte_in_process() {
    let spec = mock_spec(4, 3, 2); // 24 cells
    let serial_dir = tmp_dir("dyn_ref");
    let serial = run_serial(&serial_dir, &spec);

    for workers in [1usize, 2, 3, 7] {
        let dir = tmp_dir(&format!("dyn_{workers}"));
        resume::prepare(&dir, &spec, false).unwrap();
        let ran = run_dynamic_workers(&dir, &spec, workers);
        assert_eq!(
            cover(&ran),
            (0..spec.cells.len()).collect::<Vec<_>>(),
            "{workers} workers must cover the grid exactly once"
        );
        assert_eq!(
            report(&dir, &spec),
            serial,
            "{workers}-worker dynamic report differs from serial"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
    std::fs::remove_dir_all(&serial_dir).unwrap();
}

#[test]
fn dynamic_worker_subprocesses_match_serial_byte_for_byte() {
    let spec = mock_spec(4, 3, 1); // 12 cells
    let serial_dir = tmp_dir("dynproc_ref");
    let serial = run_serial(&serial_dir, &spec);

    for workers in [1usize, 2, 3, 7] {
        let dir = tmp_dir(&format!("dynproc_{workers}"));
        resume::prepare(&dir, &spec, false).unwrap();
        let mut children = Vec::new();
        for _ in 0..workers {
            let child = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
                .args(["sweep-worker", "--dir"])
                .arg(&dir)
                .args(["--schedule", "dynamic", "--lease-ttl-ms", "60000"])
                .spawn()
                .expect("spawning repro sweep-worker (dynamic)");
            children.push(child);
        }
        for mut child in children {
            let status = child.wait().unwrap();
            assert!(status.success(), "dynamic worker exited {status}");
        }
        assert_eq!(
            report(&dir, &spec),
            serial,
            "{workers} dynamic worker processes differ from serial"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
    std::fs::remove_dir_all(&serial_dir).unwrap();
}

// ---------------------------------------------------------------------------
// Skewed-cost grid: stealing keeps every worker busy
// ---------------------------------------------------------------------------

#[test]
fn skewed_grid_forces_stealing_and_idles_no_worker() {
    let spec = mock_spec(3, 3, 2); // 18 cells
    let serial_dir = tmp_dir("skew_ref");
    let serial = run_serial(&serial_dir, &spec);

    // Cells 0, 6 and 12 are ~40× the rest — the MNLI-vs-WNLI shape.
    // Under the static 3-shard round-robin all three would land on
    // shard 0 (index % 3 == 0) while shards 1 and 2 idle; dynamic
    // workers must instead each stay busy and cover the grid once.
    // (Costs are large relative to thread-startup jitter so no worker
    // can miss the whole grid by arriving late.)
    fn cost(index: usize) -> u64 {
        if index % 6 == 0 {
            200
        } else {
            5
        }
    }
    let workers = 3usize;
    let dir = tmp_dir("skew");
    resume::prepare(&dir, &spec, false).unwrap();
    let ran = run_dynamic_workers_with_cost(&dir, &spec, workers, cost);
    for (w, cells) in ran.iter().enumerate() {
        assert!(
            !cells.is_empty(),
            "worker {w} completed no cells while unclaimed cells remained: {ran:?}"
        );
    }
    assert_eq!(
        cover(&ran),
        (0..spec.cells.len()).collect::<Vec<_>>(),
        "skewed grid must be covered exactly once: {ran:?}"
    );
    assert_eq!(report(&dir, &spec), serial, "skewed dynamic report differs from serial");
    std::fs::remove_dir_all(&serial_dir).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// Failure diagnostics
// ---------------------------------------------------------------------------

#[test]
fn failed_worker_surfaces_exit_status_and_stderr_tail() {
    // point a real worker binary at a dir with no sweep.json: it must
    // fail, and spawn_workers must report *how* — status + stderr tail
    let dir = tmp_dir("diag");
    std::fs::create_dir_all(&dir).unwrap();
    let exe = PathBuf::from(env!("CARGO_BIN_EXE_repro"));
    let err = sweep::spawn_workers_with_exe(&exe, &dir, 1, &[])
        .expect_err("worker without a sweep.json must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("exited with"), "missing exit status: {msg}");
    assert!(
        msg.contains("sweep spec") || msg.contains("sweep.json"),
        "missing the worker's own stderr in the diagnostic: {msg}"
    );
    // the stderr capture file is kept for post-mortems
    assert!(sweep::worker_log_path(&dir, 0).exists());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn mixed_static_and_dynamic_workers_share_one_fragment_store() {
    // belt-and-braces interop: a static shard pre-completes part of the
    // grid; dynamic workers then finish the rest without touching it
    let spec = mock_spec(4, 2, 1); // 8 cells
    let serial_dir = tmp_dir("mixed_ref");
    let serial = run_serial(&serial_dir, &spec);

    let dir = tmp_dir("mixed");
    resume::prepare(&dir, &spec, false).unwrap();
    sweep::run_shard(&dir, &spec, Shard { index: 0, of: 2 }, &mut |c, _| {
        Ok(sweep::mock_cell(c))
    })
    .unwrap();
    let ran = run_dynamic_workers(&dir, &spec, 2);
    let expect: Vec<usize> = (0..spec.cells.len()).filter(|i| i % 2 == 1).collect();
    assert_eq!(cover(&ran), expect, "dynamic workers must run exactly the leftovers");
    assert_eq!(report(&dir, &spec), serial);
    std::fs::remove_dir_all(&serial_dir).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// Fleet: registered workers, mid-lease kill, elastic join
// ---------------------------------------------------------------------------

#[test]
fn fleet_registered_workers_heal_a_kill_and_match_serial_bytes() {
    let spec = mock_spec(4, 3, 1); // 12 cells
    let serial_dir = tmp_dir("fleet_ref");
    let serial = run_serial(&serial_dir, &spec);

    for workers in [1usize, 2, 3, 7] {
        let dir = tmp_dir(&format!("fleet_{workers}"));
        resume::prepare(&dir, &spec, false).unwrap();
        let cdir = resume::cells_dir(&dir);
        // A registered worker died mid-lease: its registry entry leaks
        // (no deregister) and it abandons a claim on cell 2 whose mtime
        // will age past the survivors' TTL.
        let doomed = fleet::register(&dir, "doomed-worker", 60_000).unwrap();
        std::mem::forget(doomed);
        std::fs::write(
            claim::claim_path(&cdir, 2),
            r#"{"heartbeat_ms": 1, "worker": "doomed-worker"}"#,
        )
        .unwrap();

        let guards: Vec<fleet::RegistryGuard> = (0..workers)
            .map(|w| {
                fleet::register(&dir, &format!("fleet-w{w}-of{workers}"), 60_000).unwrap()
            })
            .collect();
        let start = Barrier::new(workers);
        std::thread::scope(|s| {
            for (w, reg) in guards.iter().enumerate() {
                let (start, spec, dir) = (&start, &spec, &dir);
                s.spawn(move || {
                    let cfg = DynamicConfig::new(&format!("fw{w}"), 400);
                    start.wait();
                    sweep::run_dynamic_registered(dir, spec, &cfg, Some(reg), &mut |c, ctx| {
                        ctx.tick(); // registry heartbeat rides the lease tick
                        Ok(sweep::mock_cell(c))
                    })
                    .expect("fleet worker failed");
                });
            }
        });
        // Survivors are live; the kill victim's entry is still visible
        // at a generous TTL (its liveness evidence hasn't expired yet).
        let live = fleet::live_workers(&dir, 60_000);
        for w in 0..workers {
            let id = format!("fleet-w{w}-of{workers}");
            assert!(live.contains(&id), "{id} missing from {live:?}");
        }
        assert!(live.contains(&"doomed-worker".to_string()));
        assert_eq!(
            report(&dir, &spec),
            serial,
            "{workers}-worker fleet run differs from serial"
        );
        for g in guards {
            g.deregister();
        }
        // Once the victim's heartbeat ages past a short TTL it drops out
        // of the live set and is reclaimable — exactly the claim rule.
        std::thread::sleep(std::time::Duration::from_millis(40));
        assert_eq!(fleet::live_workers(&dir, 25), Vec::<String>::new());
        assert_eq!(fleet::reclaim_stale(&dir, 25), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }
    std::fs::remove_dir_all(&serial_dir).unwrap();
}

#[test]
fn late_joining_registered_worker_picks_up_unclaimed_cells() {
    let spec = mock_spec(4, 2, 1); // 8 cells
    let serial_dir = tmp_dir("elastic_ref");
    let serial = run_serial(&serial_dir, &spec);

    let dir = tmp_dir("elastic");
    resume::prepare(&dir, &spec, false).unwrap();
    std::thread::scope(|s| {
        let (spec, dir) = (&spec, &dir);
        let early = s.spawn(move || {
            let cfg = DynamicConfig::new("early", 60_000);
            sweep::run_dynamic(dir, spec, &cfg, &mut |c, _| {
                std::thread::sleep(std::time::Duration::from_millis(40));
                Ok(sweep::mock_cell(c))
            })
            .expect("early worker failed")
            .ran
        });
        // The sweep is well underway when the elastic worker registers:
        // joining is nothing more than register + run_dynamic_registered
        // against the same mount — it claims whatever cells remain.
        std::thread::sleep(std::time::Duration::from_millis(60));
        let reg = fleet::register(&dir, "late-joiner", 60_000).unwrap();
        assert!(fleet::live_workers(&dir, 60_000)
            .contains(&"late-joiner".to_string()));
        let cfg = DynamicConfig::new("late", 60_000);
        let late = sweep::run_dynamic_registered(dir, spec, &cfg, Some(&reg), &mut |c, _| {
            Ok(sweep::mock_cell(c))
        })
        .expect("late worker failed")
        .ran;
        assert!(!late.is_empty(), "late joiner claimed no cells");
        reg.deregister();
        assert!(!early.join().unwrap().is_empty(), "early worker claimed no cells");
    });
    assert_eq!(report(&dir, &spec), serial, "elastic-join report differs from serial");
    assert_eq!(
        fleet::live_workers(&dir, 60_000),
        Vec::<String>::new(),
        "clean exits must leave an empty registry"
    );
    std::fs::remove_dir_all(&serial_dir).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}
