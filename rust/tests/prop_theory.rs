//! Property tests for the paper's theory (Section 2.3) over randomized
//! inputs, via the first-party prop runner (seeded, replayable).

use rmmlinear::rmm::{self, sketch, variance, SketchKind};
use rmmlinear::tensor::matmul_at;
use rmmlinear::util::prop::prop_check;

#[test]
fn theorem_2_3_exact_identity() {
    // The *corrected* Theorem 2.3: an exact identity whose RHS carries the
    // +2‖X‖²‖Y‖² term the paper's proof drops (EXPERIMENTS.md
    // §Discrepancies).  Holds for arbitrary X, Y, B_proj.
    prop_check("theorem 2.3 identity", 300, |g| {
        let b = g.usize_in(2, 40);
        let x = g.tensor(b..=b, 1..=16);
        let y = g.tensor(b..=b, 1..=16);
        let b_proj = g.usize_in(1, 64);
        if variance::alpha(&x, &y) < 1e-6 {
            return; // (α+1)/α diverges
        }
        let (lhs, rhs) = variance::theorem_identity_gap(&x, &y, b_proj);
        let scale = lhs.abs().max(rhs.abs()).max(1.0);
        assert!((lhs - rhs).abs() < 1e-6 * scale, "lhs={lhs} rhs={rhs}");
    });
}

#[test]
fn theorem_2_3_bound_holds_in_training_regime() {
    // With many iid rows (the Fig. 4 regime) the dropped term is dominated
    // and the paper's stated bound holds.
    prop_check("theorem 2.3 (regime)", 200, |g| {
        let x = g.tensor(32..=32, 8..=8);
        let y = g.tensor(32..=32, 8..=8);
        let a = variance::alpha(&x, &y);
        if a < 1e-7 {
            return;
        }
        let lhs = variance::ratio_lhs(&x, &y, 16);
        let rhs = variance::bound_rhs(&x, &y);
        assert!(lhs <= rhs * (1.0 + 1e-6), "lhs={lhs} rhs={rhs} alpha={a}");
    });
}

#[test]
fn theorem_2_3_paper_statement_has_counterexamples() {
    // Scan tiny skewed shapes for a violation of the bound *as stated* —
    // documents that the discrepancy is real, not a float artifact.
    let mut found = false;
    'outer: for seed in 0..2000u64 {
        let mut g = rmmlinear::util::prop::Gen::new(seed);
        let x = g.tensor(3..=3, 1..=1);
        let y = g.tensor(3..=3, 2..=2);
        let a = variance::alpha(&x, &y);
        if a < 1e-4 {
            continue;
        }
        let lhs = variance::ratio_lhs(&x, &y, 1);
        let rhs = variance::bound_rhs(&x, &y);
        if lhs > rhs * 1.05 {
            found = true;
            break 'outer;
        }
    }
    assert!(found, "expected at least one Theorem-2.3 violation in the scan");
}

#[test]
fn lemma_2_1_nonnegative() {
    prop_check("D2_SGD >= 0", 300, |g| {
        let b = g.usize_in(2, 32);
        let x = g.tensor(b..=b, 1..=12);
        let y = g.tensor(b..=b, 1..=12);
        let v = variance::d2_sgd(&x, &y);
        assert!(v >= -1e-6 * v.abs().max(1.0), "v={v}");
    });
}

#[test]
fn lemma_2_2_nonnegative_and_monotone() {
    // Cauchy-Schwarz ⇒ paper's formula ≥ 0; and halving B_proj doubles it.
    prop_check("D2_RMM >= 0, ~ 1/B_proj", 300, |g| {
        let b = g.usize_in(2, 32);
        let x = g.tensor(b..=b, 1..=12);
        let y = g.tensor(b..=b, 1..=12);
        let v1 = variance::d2_rmm(&x, &y, 2);
        let v2 = variance::d2_rmm(&x, &y, 4);
        assert!(v1 >= -1e-6);
        assert!((v1 - 2.0 * v2).abs() <= 1e-6 * v1.abs().max(1.0));
    });
}

#[test]
fn exact_variance_dominates_paper_variance() {
    // d2_rmm_exact − d2_rmm = 2‖XᵀY‖²/B_proj ≥ 0 (the Lemma 2.2 gap).
    prop_check("exact >= paper", 300, |g| {
        let b = g.usize_in(2, 24);
        let x = g.tensor(b..=b, 1..=8);
        let y = g.tensor(b..=b, 1..=8);
        let bp = g.usize_in(1, 32);
        assert!(variance::d2_rmm_exact(&x, &y, bp) >= variance::d2_rmm(&x, &y, bp) - 1e-9);
    });
}

#[test]
fn sketch_projection_linearity() {
    // project(X+Z) = project(X) + project(Z) for the same seed — the store
    // can't break gradient linearity.
    prop_check("projection linear", 100, |g| {
        let b = g.usize_in(2, 24);
        let n = g.usize_in(1, 8);
        let x = g.tensor(b..=b, n..=n);
        let z = g.tensor(b..=b, n..=n);
        let seed = g.seed_pair();
        let bp = g.usize_in(1, b);
        let kind = match g.usize_in(0, 2) {
            0 => SketchKind::Gauss,
            1 => SketchKind::Rademacher,
            _ => SketchKind::Dct,
        };
        let mut xz = x.clone();
        xz.add_assign(&z);
        let p_sum = rmm::project(kind, &xz, bp, seed);
        let mut p1 = rmm::project(kind, &x, bp, seed);
        let p2 = rmm::project(kind, &z, bp, seed);
        p1.add_assign(&p2);
        assert!(p_sum.max_abs_diff(&p1) < 1e-3);
    });
}

#[test]
fn rmm_grad_matches_sketch_algebra_for_all_kinds() {
    prop_check("grad = (SᵀY)ᵀ(SᵀX)", 60, |g| {
        let b = g.usize_in(2, 20);
        let x = g.tensor(b..=b, 1..=6);
        let y = g.tensor(b..=b, 1..=6);
        let bp = g.usize_in(1, b);
        let seed = g.seed_pair();
        for kind in SketchKind::ALL {
            let s = sketch::sketch(kind, b, bp, seed);
            let want = matmul_at(&matmul_at(&s, &y), &matmul_at(&s, &x));
            let got = rmm::rmm_grad_w(kind, &y, &rmm::project(kind, &x, bp, seed), seed);
            assert!(got.max_abs_diff(&want) < 1e-3, "{kind:?}");
        }
    });
}

#[test]
fn variance_regression_montecarlo_matches_closed_form_for_all_kinds() {
    // Regression pin for the sampling kernels' statistical correctness:
    // the empirical variance of the sketched gradient over 200 Philox
    // seeds must match the closed-form Lemma 2.2 estimate in
    // `rmm::variance`, for every sketch family.  X and Y are fixed iid
    // normal draws, so α = ‖XᵀY‖²/(‖X‖²‖Y‖²) ≪ 1 and the paper's formula
    // is the family-agnostic leading term — the non-Gaussian families
    // (different fourth moments / sampling designs) agree to O(α) plus
    // per-family O(1/B) corrections, hence the factor-2 band.  A
    // normalization or Philox-stream regression in any sampler moves the
    // ratio far outside it.
    let mut g = rmmlinear::util::prop::Gen::new(0xC0FFEE);
    let x = g.tensor(32..=32, 6..=6);
    let y = g.tensor(32..=32, 5..=5);
    let bp = 8;
    let closed = variance::d2_rmm(&x, &y, bp);
    assert!(closed > 0.0);
    for kind in SketchKind::ALL {
        let mc = variance::d2_montecarlo(kind, &x, &y, bp, 200, 1301);
        let ratio = mc / closed;
        assert!(
            ratio > 0.5 && ratio < 2.0,
            "{kind:?}: mc={mc} closed={closed} ratio={ratio}"
        );
    }
    // Gauss additionally has an *exact* closed form (fourth moment
    // included) — pin it tightly.
    let exact = variance::d2_rmm_exact(&x, &y, bp);
    let mc = variance::d2_montecarlo(SketchKind::Gauss, &x, &y, bp, 200, 1301);
    let rel = (mc - exact).abs() / exact;
    assert!(rel < 0.25, "gauss exact form: mc={mc} formula={exact} rel={rel}");
}

#[test]
fn wtacrs_variance_montecarlo_matches_closed_form() {
    // WTA-CRS has an *exact* closed form (deterministic winners contribute
    // zero variance; the m uniform loser draws carry it all — see
    // `variance::d2_wtacrs`), so like the Gauss pin it gets a tight band,
    // not just the family-agnostic factor-2 one.  Checked across several
    // B_proj on both sides of the WTA-vs-uniform-CRS crossover.
    let mut g = rmmlinear::util::prop::Gen::new(0xC0FFEE);
    let x = g.tensor(32..=32, 6..=6);
    let y = g.tensor(32..=32, 5..=5);
    for bp in [4usize, 8, 16] {
        let closed = variance::d2_wtacrs(&x, &y, bp);
        assert!(closed > 0.0, "bp={bp}: closed={closed}");
        let mc = variance::d2_montecarlo(SketchKind::WtaCrs, &x, &y, bp, 200, 1301);
        let rel = (mc - closed).abs() / closed;
        assert!(rel < 0.25, "bp={bp}: mc={mc} formula={closed} rel={rel}");
    }
    // Degenerate full-width case: every column is a deterministic winner,
    // SSᵀ = I exactly, so both the closed form and the estimator variance
    // vanish.
    let mc_full = variance::d2_montecarlo(SketchKind::WtaCrs, &x, &y, 64, 20, 1301);
    assert!(variance::d2_wtacrs(&x, &y, 64) == 0.0);
    assert!(mc_full.abs() < 1e-6, "full-width WTA-CRS must be exact: {mc_full}");
}

#[test]
fn approx_vjp_grad_w_variance_is_the_underlying_familys() {
    // The approximate-VJP estimator sketches only the grad-weight path, so
    // its ∂W variance is the underlying family's closed form *unchanged* —
    // pinned as an identity for every family, and against Monte Carlo for
    // the two families with exact forms (the avjp ∂W estimator is literally
    // the family's estimator, so the same MC run covers it).
    let mut g = rmmlinear::util::prop::Gen::new(0xC0FFEE);
    let x = g.tensor(32..=32, 6..=6);
    let y = g.tensor(32..=32, 5..=5);
    let bp = 8;
    for kind in SketchKind::ALL {
        assert_eq!(
            variance::d2_approx_vjp(kind, &x, &y, bp).to_bits(),
            variance::d2_family(kind, &x, &y, bp).to_bits(),
            "{kind:?}: avjp grad-W variance must equal the family's"
        );
    }
    for kind in [SketchKind::Gauss, SketchKind::WtaCrs] {
        let closed = variance::d2_approx_vjp(kind, &x, &y, bp);
        let mc = variance::d2_montecarlo(kind, &x, &y, bp, 200, 1301);
        let rel = (mc - closed).abs() / closed;
        assert!(rel < 0.25, "{kind:?}: mc={mc} formula={closed} rel={rel}");
    }
}

#[test]
fn identity_sketch_recovers_exact_gradient() {
    // ρ = 1 with an orthonormal S (full-width DCT, no subsample collision
    // needed — use B_proj = B with rowsample replaced by full transform):
    // SSᵀ = I exactly for the structured transforms when every row is kept
    // exactly once; here we verify the weaker, always-true statement that
    // the exact path equals YᵀX.
    prop_check("exact grad", 100, |g| {
        let b = g.usize_in(2, 16);
        let x = g.tensor(b..=b, 1..=6);
        let y = g.tensor(b..=b, 1..=6);
        let exact = rmm::exact_grad_w(&y, &x);
        let manual = matmul_at(&y, &x);
        assert!(exact.max_abs_diff(&manual) < 1e-5);
    });
}
