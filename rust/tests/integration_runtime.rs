//! Integration tests over the real PJRT runtime + AOT artifacts.
//!
//! These need `make artifacts` to have run; they use the `tiny*` variants
//! (seconds to compile).  If artifacts are missing the tests panic with a
//! pointed message rather than silently passing.  The whole file is gated
//! on the `xla` cargo feature: without the PJRT runtime there is nothing
//! real to integrate against (the stub engine fails by design).
#![cfg(feature = "xla")]

use std::path::Path;

use rmmlinear::config::TrainConfig;
use rmmlinear::coordinator::Trainer;
use rmmlinear::data::{Batcher, Split, Task, TaskGen, Tokenizer};
use rmmlinear::memory::MemoryModel;
use rmmlinear::runtime::{Engine, Manifest, Role};

fn manifest() -> Manifest {
    Manifest::load(Path::new("artifacts"))
        .expect("artifacts missing — run `make artifacts` before `cargo test`")
}

fn cfg(steps: usize) -> TrainConfig {
    TrainConfig {
        steps,
        warmup_steps: (steps / 8).min(4),
        lr: 2e-3,
        log_every: usize::MAX,
        eval_every: usize::MAX,
        ..Default::default()
    }
}

#[test]
fn manifest_loads_and_specs_are_consistent() {
    let m = manifest();
    assert!(m.variants.len() >= 3);
    for v in m.variants.values() {
        for (ename, e) in &v.entries {
            assert!(!e.args.is_empty(), "{}.{ename}", v.name);
            assert!(!e.outputs.is_empty());
            // params lead the arg list, in param_spec order
            let n_params = e.args.iter().filter(|a| a.role == Role::Param).count();
            assert!(e.args[..n_params].iter().all(|a| a.role == Role::Param));
            if ename == "bwd" {
                // fwd residual outputs == bwd residual args (names + shapes)
                let fwd = &v.entries["fwd"];
                let f: Vec<_> = fwd.residual_outputs().collect();
                let b: Vec<_> = e.residual_args().collect();
                assert_eq!(f.len(), b.len(), "{}", v.name);
                for (fo, ba) in f.iter().zip(&b) {
                    assert_eq!(fo.name, ba.name);
                    assert_eq!(fo.shape, ba.shape);
                }
                let n_grads =
                    e.outputs.iter().filter(|o| o.role == Role::Grad).count();
                assert_eq!(n_grads, n_params, "{}", v.name);
            }
        }
        // init params blob splits exactly across the param specs
        let params = m.load_init_params(v).expect("init params");
        assert_eq!(params.len(), {
            let e = v.entries.values().next().unwrap();
            e.args.iter().filter(|a| a.role == Role::Param).count()
        });
    }
}

#[test]
fn tiny_baseline_overfits_a_fixed_batch() {
    // Strongest end-to-end correctness signal: repeated steps on one batch
    // must drive its loss down (fwd, residual store, bwd and the optimizer
    // all have to be right for this to happen).
    let m = manifest();
    let variant = m.variant("tiny_cls2_r100_gauss").unwrap();
    let mut engine = Engine::cpu().unwrap();
    let tok = Tokenizer::new(variant.config.vocab_size);
    let c = cfg(40);
    let mut trainer = Trainer::new(&m, variant, Task::Cola, c.clone()).unwrap();
    let gen = TaskGen::new(Task::Cola, &tok, variant.config.seq_len, 1);
    let batch = Batcher::new(&gen, Split::Train, variant.config.batch_size, 0)
        .next()
        .unwrap();
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..c.steps {
        let s = trainer.train_step(&mut engine, &batch).unwrap();
        assert!(s.loss.is_finite());
        first.get_or_insert(s.loss);
        last = s.loss;
    }
    let first = first.unwrap();
    assert!(
        last < first - 0.08,
        "loss did not overfit the fixed batch: {first} -> {last}"
    );
    // store must be empty between steps
    assert!(trainer.store.is_empty());
}

#[test]
fn tiny_rmm_trains_and_saves_memory() {
    let m = manifest();
    let mut engine = Engine::cpu().unwrap();
    let tok = Tokenizer::new(64);
    let mut peaks = Vec::new();
    for vname in ["tiny_cls2_r100_gauss", "tiny_cls2_r50_gauss"] {
        let variant = m.variant(vname).unwrap();
        let c = cfg(10);
        let mut trainer = Trainer::new(&m, variant, Task::Cola, c.clone()).unwrap();
        let gen = TaskGen::new(Task::Cola, &tok, variant.config.seq_len, 1);
        let mut batches =
            Batcher::new(&gen, Split::Train, variant.config.batch_size, 0);
        for _ in 0..c.steps {
            let batch = batches.next().unwrap();
            let s = trainer.train_step(&mut engine, &batch).unwrap();
            assert!(s.loss.is_finite(), "{vname}");
        }
        peaks.push(trainer.peak_residual_bytes);
    }
    assert!(
        peaks[1] < peaks[0],
        "rmm variant should stage fewer residual bytes: {peaks:?}"
    );
}

#[test]
fn measured_store_matches_memory_model() {
    let m = manifest();
    let mut engine = Engine::cpu().unwrap();
    let tok = Tokenizer::new(64);
    for vname in ["tiny_cls2_r100_gauss", "tiny_cls2_r50_gauss"] {
        let variant = m.variant(vname).unwrap();
        let mut trainer = Trainer::new(&m, variant, Task::Cola, cfg(1)).unwrap();
        let gen = TaskGen::new(Task::Cola, &tok, variant.config.seq_len, 1);
        let batch = Batcher::new(&gen, Split::Train, variant.config.batch_size, 0)
            .next()
            .unwrap();
        trainer.train_step(&mut engine, &batch).unwrap();
        let model = MemoryModel::new(variant.config.geometry(), variant.config.rho);
        assert_eq!(
            trainer.peak_residual_bytes,
            model.residual_bytes(),
            "{vname}: analytic model must mirror the tape exactly"
        );
    }
}

#[test]
fn training_is_deterministic_given_seed() {
    let m = manifest();
    let mut engine = Engine::cpu().unwrap();
    let tok = Tokenizer::new(64);
    let variant = m.variant("tiny_cls2_r50_gauss").unwrap();
    let run = |engine: &mut Engine| -> Vec<f64> {
        let c = cfg(5);
        let mut trainer = Trainer::new(&m, variant, Task::Cola, c.clone()).unwrap();
        let gen = TaskGen::new(Task::Cola, &tok, variant.config.seq_len, 1);
        let mut batches =
            Batcher::new(&gen, Split::Train, variant.config.batch_size, 0);
        (0..c.steps)
            .map(|_| {
                trainer
                    .train_step(engine, &batches.next().unwrap())
                    .unwrap()
                    .loss
            })
            .collect()
    };
    let a = run(&mut engine);
    let b = run(&mut engine);
    assert_eq!(a, b, "same seed must reproduce the loss trace exactly");
}

#[test]
fn different_seeds_give_different_rmm_noise() {
    let m = manifest();
    let mut engine = Engine::cpu().unwrap();
    let tok = Tokenizer::new(64);
    let variant = m.variant("tiny_cls2_r50_gauss").unwrap();
    let grads_with_seed = |engine: &mut Engine, seed: u64| -> Vec<f32> {
        let mut c = cfg(1);
        c.seed = seed;
        let mut trainer = Trainer::new(&m, variant, Task::Cola, c).unwrap();
        // same data seed for both runs — only the sketch seed differs
        let gen = TaskGen::new(Task::Cola, &tok, variant.config.seq_len, 99);
        let batch = Batcher::new(&gen, Split::Train, variant.config.batch_size, 0)
            .next()
            .unwrap();
        trainer.train_step(engine, &batch).unwrap();
        trainer.params[4].clone() // first block weight after one update
    };
    let a = grads_with_seed(&mut engine, 1);
    let b = grads_with_seed(&mut engine, 2);
    assert_ne!(a, b, "different sketch seeds must perturb the update");
}

#[test]
fn pallas_kernel_variant_runs_through_pjrt() {
    // The tinyk variant lowers the *Pallas kernel path* (fused seeded
    // projection + tiled matmul, interpret mode) into its HLO; executing it
    // proves the L1 kernels survive the full AOT → PJRT round trip.
    let m = manifest();
    let mut engine = Engine::cpu().unwrap();
    let tok = Tokenizer::new(64);
    let variant = m.variant("tinyk_cls2_r50_gauss").unwrap();
    assert!(variant.config.use_kernels);
    let c = cfg(3);
    let mut trainer = Trainer::new(&m, variant, Task::Cola, c.clone()).unwrap();
    let gen = TaskGen::new(Task::Cola, &tok, variant.config.seq_len, 1);
    let mut batches = Batcher::new(&gen, Split::Train, variant.config.batch_size, 0);
    for _ in 0..c.steps {
        let s = trainer
            .train_step(&mut engine, &batches.next().unwrap())
            .unwrap();
        assert!(s.loss.is_finite());
    }
}

#[test]
fn kernel_and_jnp_variants_agree_numerically() {
    // tinyk (pallas kernels) and tiny (pure jnp) share geometry, init
    // params, sketch seeds and data: their losses must match to float
    // tolerance — the strongest cross-layer equivalence check we can run
    // through the real runtime.
    let m = manifest();
    let mut engine = Engine::cpu().unwrap();
    let tok = Tokenizer::new(64);
    let mut losses = Vec::new();
    for vname in ["tiny_cls2_r50_gauss", "tinyk_cls2_r50_gauss"] {
        let variant = m.variant(vname).unwrap();
        let mut trainer = Trainer::new(&m, variant, Task::Cola, cfg(2)).unwrap();
        let gen = TaskGen::new(Task::Cola, &tok, variant.config.seq_len, 1);
        let mut batches =
            Batcher::new(&gen, Split::Train, variant.config.batch_size, 0);
        let mut trace = Vec::new();
        for _ in 0..2 {
            trace.push(
                trainer
                    .train_step(&mut engine, &batches.next().unwrap())
                    .unwrap()
                    .loss,
            );
        }
        losses.push(trace);
    }
    for (a, b) in losses[0].iter().zip(&losses[1]) {
        assert!(
            (a - b).abs() < 1e-3 * a.abs().max(1.0),
            "kernel vs jnp loss mismatch: {a} vs {b}"
        );
    }
}

#[test]
fn evaluate_produces_metric_in_range() {
    let m = manifest();
    let mut engine = Engine::cpu().unwrap();
    let tok = Tokenizer::new(64);
    let variant = m.variant("tiny_cls2_r100_gauss").unwrap();
    let mut trainer = Trainer::new(&m, variant, Task::Cola, cfg(1)).unwrap();
    let score = trainer.evaluate(&mut engine, &tok).unwrap();
    assert!((-100.0..=100.0).contains(&score), "matthews% out of range: {score}");
}

#[test]
fn task_head_mismatch_is_rejected() {
    let m = manifest();
    let variant = m.variant("tiny_cls2_r100_gauss").unwrap();
    assert!(Trainer::new(&m, variant, Task::Mnli, cfg(1)).is_err());
    assert!(Trainer::new(&m, variant, Task::Stsb, cfg(1)).is_err());
}
