//! Seeded chaos suite for the claim/lease/resume/session stack
//! (`chaos::*` + `sweep::*`).
//!
//! The contract under test (see `sweep/mod.rs` "Chaos knobs" and
//! `chaos/mod.rs` for the canonical prose):
//!
//! * **Results are chaos-invariant** — worker kills, corrupted/torn
//!   fragment commits, transient claim-store IO errors and clock skew
//!   may cost retries, reclaims and respawns, but the merged report is
//!   byte-identical to a fault-free serial run.  Pinned here for
//!   worker counts {1, 2, 3, 7} through real `repro sweep-worker`
//!   subprocesses under the supervising orchestrator.
//! * **Replay identity** — the fault schedule is a pure function of
//!   (seed, profile, slot, generation): the same seed fires the same
//!   faults, in the same order, at the same hit counts.
//! * **Kill semantics** — a killed worker leaves its claim behind
//!   (no `Drop` runs), the lease goes stale, and a successor reclaims
//!   and finishes the cell.
//! * **Respawn budget** — the supervisor relaunches crashed workers
//!   while the budget lasts; a crash past the budget surfaces the
//!   exit status (the chaos kill code is 86) instead of hanging.
//!
//! Chaos installation is process-global, so every test serializes on
//! [`CHAOS_LOCK`] and clears the schedule on both sides of its work —
//! in-process serial references must run fault-free.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use rmmlinear::chaos::{self, FaultAction, InstallOpts};
use rmmlinear::config::TrainConfig;
use rmmlinear::sweep::{
    self,
    claim::{self, ClaimAttempt},
    merge, resume, DynamicConfig, Shard, SweepSpec,
};
use rmmlinear::util::json::Json;

/// One lock around every chaos install in this binary: the schedule,
/// hit counters and clock skew are process-global statics.
static CHAOS_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    let g = CHAOS_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    chaos::clear();
    g
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("rmm_prop_chaos_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Small mock grid for the in-process tests.
fn mock_spec() -> SweepSpec {
    let mut spec = SweepSpec::new("mock", TrainConfig::default());
    for t in 0..3usize {
        for r in 0..2usize {
            spec.push(
                format!("v{t}_r{r}"),
                format!("task{t}"),
                1.0 / (r + 1) as f64,
                if t % 2 == 0 { "gauss" } else { "dct" },
                t as u64,
                t * 8,
            );
        }
    }
    spec
}

fn report(dir: &Path, spec: &SweepSpec) -> String {
    Json::Arr(merge::merge(dir, spec).expect("sweep incomplete")).to_string_pretty()
}

/// Fault-free serial reference (asserts chaos is off so a leaked
/// install can never silently fault the reference itself).
fn run_serial<F>(dir: &Path, spec: &SweepSpec, runner: &mut F) -> String
where
    F: FnMut(&sweep::Cell) -> Json,
{
    assert!(!chaos::enabled(), "serial reference must run fault-free");
    resume::prepare(dir, spec, false).unwrap();
    sweep::run_shard(dir, spec, Shard::SERIAL, &mut |c, _| Ok(runner(c))).unwrap();
    report(dir, spec)
}

fn install(profile: &str, generation: u32) {
    chaos::install(&InstallOpts {
        seed: 11,
        profile: profile.to_string(),
        slot: 0,
        generation,
        exit_on_kill: false,
        verbose: false,
    })
    .unwrap();
}

#[test]
fn compiled_schedules_are_deterministic_slot_scoped_and_generation_filtered() {
    let _g = lock();
    for profile in chaos::PROFILES {
        chaos::validate_profile(profile).unwrap();
        let a = chaos::compile(9, profile, 2).unwrap();
        let b = chaos::compile(9, profile, 2).unwrap();
        assert_eq!(a, b, "compile must be deterministic for '{profile}'");
        assert!(
            a.iter().all(|e| e.slot == Some(2)),
            "named-profile entries must be scoped to the compiling slot"
        );
    }
    // crash profile, slot 0: the kill is scheduled within the first
    // three sched.cell hits at generation 0 …
    assert!(chaos::compile(11, "crash", 0)
        .unwrap()
        .iter()
        .any(|e| e.action == FaultAction::Kill));
    install("crash", 0);
    let kills = (0..5)
        .filter(|_| chaos::fault("sched.cell").is_err())
        .count();
    assert_eq!(kills, 1, "exactly one in-process kill must fire");
    // … and is filtered out for a respawned (generation > 0) worker.
    install("crash", 1);
    for _ in 0..5 {
        chaos::fault("sched.cell").expect("generation 1 must not re-kill");
    }
    chaos::clear();
}

#[test]
fn transient_claim_errors_degrade_to_retries_and_replay_identically() {
    let _g = lock();
    let spec = mock_spec();
    let serial = run_serial(&tmp_dir("retry_ref"), &spec, &mut |c| sweep::mock_cell(c));

    let mut fired_runs = Vec::new();
    for round in 0..2 {
        let dir = tmp_dir(&format!("retry_{round}"));
        resume::prepare(&dir, &spec, false).unwrap();
        install("claim.create@0=err:interrupted;claim.refresh@0=err:timedout", 0);
        let cfg = DynamicConfig::new("w0", 60_000);
        sweep::run_dynamic(&dir, &spec, &cfg, &mut |c, _| Ok(sweep::mock_cell(c)))
            .expect("transient chaos errors must heal through the retry layer");
        let fired = chaos::fired();
        chaos::clear();
        assert!(
            fired.iter().any(|l| l.contains("claim.create@0")),
            "the scheduled claim fault must actually fire: {fired:?}"
        );
        assert_eq!(report(&dir, &spec), serial, "chaos run must match serial bytes");
        fired_runs.push(fired);
        std::fs::remove_dir_all(&dir).unwrap();
    }
    assert_eq!(
        fired_runs[0], fired_runs[1],
        "same seed + schedule must replay the identical fault sequence"
    );
}

#[test]
fn corrupted_fragment_commits_heal_before_publish() {
    let _g = lock();
    let spec = mock_spec();
    let serial = run_serial(&tmp_dir("corrupt_ref"), &spec, &mut |c| sweep::mock_cell(c));

    let dir = tmp_dir("corrupt");
    resume::prepare(&dir, &spec, false).unwrap();
    // first staged write garbage, fourth torn in half: commit
    // verification must catch both and restage clean bytes
    install("fragment.stage@0=garbage;fragment.stage@3=truncate", 0);
    let cfg = DynamicConfig::new("w0", 60_000);
    sweep::run_dynamic(&dir, &spec, &cfg, &mut |c, _| Ok(sweep::mock_cell(c)))
        .expect("corrupted commits must heal via verified re-commit");
    let fired = chaos::fired();
    chaos::clear();
    assert!(
        fired.iter().any(|l| l.contains("garbage"))
            && fired.iter().any(|l| l.contains("truncate")),
        "both corruptions must fire: {fired:?}"
    );
    assert_eq!(report(&dir, &spec), serial, "healed run must match serial bytes");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn in_process_kill_leaves_the_claim_for_stale_lease_reclaim() {
    let _g = lock();
    let spec = mock_spec();
    let serial = run_serial(&tmp_dir("kill_ref"), &spec, &mut |c| sweep::mock_cell(c));

    let dir = tmp_dir("kill");
    resume::prepare(&dir, &spec, false).unwrap();
    install("sched.cell@0=kill", 0);
    let cfg = DynamicConfig::new("victim", 60_000);
    let err = sweep::run_dynamic(&dir, &spec, &cfg, &mut |c, _| Ok(sweep::mock_cell(c)))
        .expect_err("an in-process kill must surface as an error");
    chaos::clear();
    assert!(format!("{err:#}").contains("chaos"), "unexpected error: {err:#}");
    // the guard was deliberately leaked: the first claimed cell's
    // lease survives the "crash" exactly like a SIGKILLed process
    let cdir = resume::cells_dir(&dir);
    assert!(
        claim::claim_path(&cdir, 0).exists(),
        "kill must leave the claim behind for the stale-lease machinery"
    );
    // a successor with a short TTL reclaims and finishes the grid
    std::thread::sleep(std::time::Duration::from_millis(80));
    let cfg = DynamicConfig::new("successor", 50);
    sweep::run_dynamic(&dir, &spec, &cfg, &mut |c, _| Ok(sweep::mock_cell(c)))
        .expect("successor must reclaim the stale lease and finish");
    assert_eq!(report(&dir, &spec), serial, "healed run must match serial bytes");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The acceptance pin: a fixed-seed chaos run — worker kill mid-lease,
/// corrupted fragment commit, transient claim-store IO, clock skew —
/// through real supervised worker processes merges byte-identically to
/// the fault-free serial reference, for 1, 2, 3 and 7 workers, on the
/// seeded synthetic workload grid.
#[test]
fn chaos_matrix_matches_fault_free_serial() {
    let _g = lock();
    let spec = sweep::synth_spec(7, "easy").unwrap();
    let mut synth = |c: &sweep::Cell| sweep::synth_cell(&spec.experiment, c);
    let serial = run_serial(&tmp_dir("matrix_ref"), &spec, &mut synth);

    let exe = PathBuf::from(env!("CARGO_BIN_EXE_repro"));
    for workers in [1usize, 2, 3, 7] {
        let dir = tmp_dir(&format!("matrix_{workers}"));
        resume::prepare(&dir, &spec, false).unwrap();
        let extra: Vec<String> = [
            "--schedule",
            "dynamic",
            "--lease-ttl-ms",
            "1200",
            "--chaos-seed",
            "11",
            "--chaos-profile",
            "crash",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        sweep::spawn_workers_supervised(&exe, &dir, workers, &extra, 3)
            .expect("supervised chaos sweep must complete within the respawn budget");
        assert_eq!(
            report(&dir, &spec),
            serial,
            "{workers}-worker chaos run must merge byte-identically to serial"
        );
        if workers == 1 {
            // slot 0 of the crash profile dies and respawns: the gen-0
            // log carries fired faults, and the gen-1 log exists
            let gen0 =
                std::fs::read_to_string(sweep::worker_log_path(&dir, 0)).unwrap();
            assert!(gen0.contains("chaos["), "gen-0 log missing fired faults:\n{gen0}");
            assert!(
                sweep::worker_log_path_gen(&dir, 0, 1).exists(),
                "kill + respawn must leave a gen-1 worker log"
            );
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn same_seed_replays_the_identical_fault_sequence_across_runs() {
    let _g = lock();
    let spec = sweep::synth_spec(7, "easy").unwrap();
    let exe = PathBuf::from(env!("CARGO_BIN_EXE_repro"));

    let chaos_lines = |dir: &Path, gen: u32| -> Vec<String> {
        let path = sweep::worker_log_path_gen(dir, 0, gen);
        std::fs::read_to_string(path)
            .unwrap_or_default()
            .lines()
            .filter(|l| l.contains("chaos["))
            .map(str::to_string)
            .collect()
    };

    let mut runs = Vec::new();
    for round in 0..2 {
        let dir = tmp_dir(&format!("replay_{round}"));
        resume::prepare(&dir, &spec, false).unwrap();
        let extra: Vec<String> = [
            "--schedule",
            "dynamic",
            "--lease-ttl-ms",
            "800",
            "--chaos-seed",
            "11",
            "--chaos-profile",
            "crash",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        sweep::spawn_workers_supervised(&exe, &dir, 1, &extra, 3).unwrap();
        let gen0 = chaos_lines(&dir, 0);
        let mut all = gen0.clone();
        all.extend(chaos_lines(&dir, 1));
        all.sort();
        assert!(!gen0.is_empty(), "gen-0 must fire at least one fault");
        runs.push((gen0, all));
        std::fs::remove_dir_all(&dir).unwrap();
    }
    assert_eq!(
        runs[0].0, runs[1].0,
        "gen-0 fault sequences must be identical across same-seed runs"
    );
    assert_eq!(
        runs[0].1, runs[1].1,
        "the full fired-fault set must be identical across same-seed runs"
    );
}

#[test]
fn supervisor_respawns_within_budget_and_surfaces_exhaustion() {
    let _g = lock();
    let spec = mock_spec();
    let exe = PathBuf::from(env!("CARGO_BIN_EXE_repro"));
    let extra: Vec<String> = [
        "--schedule",
        "dynamic",
        "--lease-ttl-ms",
        "500",
        "--chaos-seed",
        "11",
        "--chaos-profile",
        "w0:sched.cell@0=kill",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();

    // budget 0 = historical fail-fast: the chaos kill (exit code 86)
    // must surface with its exit status
    let dir = tmp_dir("budget0");
    resume::prepare(&dir, &spec, false).unwrap();
    let err = sweep::spawn_workers_supervised(&exe, &dir, 1, &extra, 0)
        .expect_err("a kill with no respawn budget must fail the sweep");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("exited with") && msg.contains("86"),
        "diagnostic must carry the chaos kill exit status: {msg}"
    );
    std::fs::remove_dir_all(&dir).unwrap();

    // with budget, the respawned generation (kills filtered) finishes
    let serial = run_serial(&tmp_dir("budget_ref"), &spec, &mut |c| sweep::mock_cell(c));
    let dir = tmp_dir("budget2");
    resume::prepare(&dir, &spec, false).unwrap();
    sweep::spawn_workers_supervised(&exe, &dir, 1, &extra, 2)
        .expect("one respawn must absorb the scheduled kill");
    assert_eq!(report(&dir, &spec), serial, "respawned run must match serial bytes");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn clock_skew_shifts_heartbeats_but_leases_stay_coherent() {
    let _g = lock();
    let before = claim::now_ms();
    install("clock@0=skew:5000", 0);
    let skewed = claim::now_ms();
    assert!(
        skewed.saturating_sub(before) >= 5_000,
        "installed skew must shift now_ms (before {before}, after {skewed})"
    );

    // claim written by the skewed worker: its heartbeat is ~5 s in an
    // honest reader's future
    let dir = tmp_dir("skew");
    let cdir = resume::cells_dir(&dir);
    std::fs::create_dir_all(&cdir).unwrap();
    match claim::try_claim(&cdir, 0, "skewed-writer", 60_000).unwrap() {
        ClaimAttempt::Won(guard) => std::mem::forget(guard), // keep the claim alive
        ClaimAttempt::Held => panic!("first claim on an empty dir must win"),
    }
    chaos::clear(); // back to the honest clock

    // within one TTL of the future the heartbeat is trusted (age 0) …
    assert!(
        matches!(claim::try_claim(&cdir, 0, "reader-a", 60_000).unwrap(), ClaimAttempt::Held),
        "mildly-future heartbeat must read as live"
    );
    // … past it the embedded clock is disbelieved and the fresh mtime
    // keeps the lease alive —
    assert!(
        matches!(claim::try_claim(&cdir, 0, "reader-b", 1_000).unwrap(), ClaimAttempt::Held),
        "future-skewed heartbeat must fall back to (fresh) mtime, not get robbed"
    );
    // — until the mtime itself goes stale and the cell is reclaimed.
    std::thread::sleep(std::time::Duration::from_millis(80));
    assert!(
        matches!(claim::try_claim(&cdir, 0, "reader-c", 10).unwrap(), ClaimAttempt::Won(_)),
        "stale-by-mtime skewed claim must be reclaimable"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
