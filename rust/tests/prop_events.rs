//! Event-contract suite for the sweep daemon (`daemon::*`).
//!
//! The contract under test (see `sweep/mod.rs` "Daemon queue + event
//! contract" for the canonical prose):
//!
//! * **Replay identity** — the JSONL tee at `<queue>/events.jsonl` is a
//!   faithful witness: `daemon::events::parse_lines` reconstructs the
//!   typed stream the daemon emitted *exactly*, ids and order included.
//! * **Tolerant parsing** — CRLF line endings, torn trailing lines and
//!   unknown event types degrade to per-line diagnostics, never a hard
//!   error, and never consume an event id.
//! * **Daemon-vs-CLI byte identity** — a sweep served through the queue
//!   merges to the same report bytes as a direct serial run, for every
//!   worker count, because the fragment store (not the event log) is
//!   the only state.
//! * **Crash = resume** — a daemon killed mid-sweep leaves the spec in
//!   `active/` and its fragments on disk; a restarted daemon finishes
//!   exactly the missing cells and publishes the identical report.
//!
//! The event sink and the chaos schedule are process-global, so every
//! test serializes on [`EVENTS_LOCK`] and clears both on entry.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use rmmlinear::bench_harness as bench;
use rmmlinear::config::TrainConfig;
use rmmlinear::daemon::{self, events, queue, DaemonOpts};
use rmmlinear::daemon::events::EventKind;
use rmmlinear::session::Session;
use rmmlinear::sweep::{self, merge, resume, Shard, SweepSpec};

/// One lock around every daemon run and chaos install in this binary:
/// the event sink, its id counter and the fault schedule are statics.
static EVENTS_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    let g = EVENTS_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    rmmlinear::chaos::clear();
    let _ = events::clear(); // drain any sink a failed test leaked
    g
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("rmm_prop_events_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Fault-free serial reference in the daemon's exact report byte format
/// (the same cold-session path `sweep-selftest` uses).
fn serial_report(tag: &str, spec: &SweepSpec) -> String {
    assert!(!rmmlinear::chaos::enabled(), "serial reference must run fault-free");
    let dir = tmp_dir(tag);
    resume::prepare(&dir, spec, false).unwrap();
    let mut cold = Session::data_only(false);
    sweep::run_shard(&dir, spec, Shard::SERIAL, &mut |c, ctx| {
        bench::runner::run_cell(&mut cold, spec, c, ctx)
    })
    .unwrap();
    let bytes = daemon::report_bytes(merge::merge(&dir, spec).unwrap());
    std::fs::remove_dir_all(&dir).unwrap();
    bytes
}

fn opts(q: &Path, workers: usize) -> DaemonOpts {
    DaemonOpts {
        queue: q.to_path_buf(),
        workers,
        lease_ttl_ms: 60_000,
        drain: true,
        ..DaemonOpts::default()
    }
}

#[test]
fn teed_log_replay_parses_back_to_the_emitted_stream_exactly() {
    let _g = lock();
    let q = tmp_dir("tee");
    let spec = sweep::selftest_spec();
    queue::enqueue(&q, "alpha", "mock", &spec).unwrap();

    let mut o = opts(&q, 1);
    o.replay_verify = true; // the daemon's own round-trip check must also pass
    let summary = daemon::run(&o).unwrap();
    assert_eq!(summary.merged, 1);
    assert_eq!(summary.rejected, 0);

    // External replay: the raw tee reconstructs the emitted stream
    // exactly — ids, order, payloads and timestamps.
    let log = std::fs::read_to_string(q.join("events.jsonl")).unwrap();
    let parsed = events::parse_lines(&log);
    assert!(parsed.diagnostics.is_empty(), "clean log: {:?}", parsed.diagnostics);
    assert_eq!(parsed.events, summary.events, "tee must round-trip the stream");

    // Shape: bracketed by daemon_started/stopped, with the full
    // queued -> started -> per-cell -> merged arc in between.
    let evs = &summary.events;
    assert!(matches!(evs.first().unwrap().kind, EventKind::DaemonStarted { .. }));
    assert!(matches!(evs.last().unwrap().kind, EventKind::DaemonStopped { sweeps: 1 }));
    assert!((1..=u64::MAX).zip(evs).all(|(want, e)| e.id == want), "ids start at 1 and are gapless");
    let count = |pred: fn(&EventKind) -> bool| evs.iter().filter(|e| pred(&e.kind)).count();
    assert_eq!(count(|k| matches!(k, EventKind::SweepQueued { .. })), 1);
    assert_eq!(count(|k| matches!(k, EventKind::SweepStarted { .. })), 1);
    assert_eq!(count(|k| matches!(k, EventKind::SweepMerged { .. })), 1);
    let cells = spec.cells.len();
    assert_eq!(count(|k| matches!(k, EventKind::CellClaimed { .. })), cells);
    assert_eq!(count(|k| matches!(k, EventKind::CellDone { .. })), cells);
    assert_eq!(count(|k| matches!(k, EventKind::FragmentCommitted { .. })), cells);
    for e in evs {
        if let EventKind::CellClaimed { sweep, .. } = &e.kind {
            assert_eq!(sweep, "alpha__mock", "library hooks must carry the sweep label");
        }
    }

    // Tolerance on the same real log: CRLF endings, an unknown event
    // type and a torn trailing line cost diagnostics, not events.
    let mangled = format!(
        "{}\r\n{{\"type\": \"sweep_paused\", \"sweep\": \"x\"}}\r\n{{\"type\": \"sweep_m",
        log.trim_end().replace('\n', "\r\n"),
    );
    let tolerant = events::parse_lines(&mangled);
    assert_eq!(tolerant.events, summary.events, "CRLF + junk must not perturb the stream");
    assert_eq!(tolerant.diagnostics.len(), 2, "{:?}", tolerant.diagnostics);
    assert!(tolerant.diagnostics[0].contains("unknown event type"));

    // The daemon-written report carries the exact serial bytes.
    let report = std::fs::read_to_string(q.join("reports").join("alpha__mock.json")).unwrap();
    assert_eq!(report, serial_report("tee_ref", &spec));
    std::fs::remove_dir_all(&q).unwrap();
}

/// The acceptance pin: a queued sweep merges byte-identically to a
/// direct serial run for 1, 2, 3 and 7 warm in-process workers.
#[test]
fn daemon_reports_match_direct_serial_runs_across_worker_counts() {
    let _g = lock();
    let spec = sweep::synth_spec(7, "easy").unwrap();
    let serial = serial_report("counts_ref", &spec);
    for workers in [1usize, 2, 3, 7] {
        let q = tmp_dir(&format!("counts_{workers}"));
        queue::enqueue(&q, "lane", "synth", &spec).unwrap();
        let summary = daemon::run(&opts(&q, workers)).unwrap();
        assert_eq!(summary.merged, 1, "{workers} workers");
        let report =
            std::fs::read_to_string(q.join("reports").join("lane__synth.json")).unwrap();
        assert_eq!(
            report, serial,
            "{workers}-worker daemon report must match direct serial bytes"
        );
        std::fs::remove_dir_all(&q).unwrap();
    }
}

/// With one worker the full event sequence is deterministic: two fresh
/// runs agree on everything but wall-clock timestamps, and a seeded
/// transient-fault schedule (healed inside the retry layer) changes
/// nothing either.
#[test]
fn same_seed_daemon_runs_emit_identical_event_streams_modulo_timing() {
    let _g = lock();
    let spec = sweep::synth_spec(3, "easy").unwrap();
    let normalize = |s: &daemon::DaemonSummary| -> Vec<events::Event> {
        s.events
            .iter()
            .map(|e| {
                let mut e = e.with_t0();
                // queue paths differ per run; blank them out too
                if let EventKind::DaemonStarted { queue, .. } = &mut e.kind {
                    *queue = String::new();
                }
                e
            })
            .collect()
    };
    let mut streams = Vec::new();
    for (round, chaos) in [(0, false), (1, false), (2, true)] {
        let q = tmp_dir(&format!("seq_{round}"));
        queue::enqueue(&q, "lane", "synth", &spec).unwrap();
        if chaos {
            // transient dequeue fault: heals under io_retry, so the
            // *observable* event stream must be untouched
            rmmlinear::chaos::install(&rmmlinear::chaos::InstallOpts {
                seed: 11,
                profile: "daemon.dequeue@0=err:interrupted".to_string(),
                slot: 0,
                generation: 0,
                exit_on_kill: false,
                verbose: false,
            })
            .unwrap();
        }
        let summary = daemon::run(&opts(&q, 1)).unwrap();
        if chaos {
            let fired = rmmlinear::chaos::fired();
            rmmlinear::chaos::clear();
            assert!(
                fired.iter().any(|l| l.contains("daemon.dequeue@0")),
                "the scheduled dequeue fault must actually fire: {fired:?}"
            );
        }
        streams.push(normalize(&summary));
        std::fs::remove_dir_all(&q).unwrap();
    }
    assert_eq!(streams[0], streams[1], "same work must emit the same stream");
    assert_eq!(streams[0], streams[2], "healed transient faults must be invisible");
}

#[test]
fn lane_depth_cap_sheds_excess_specs_with_typed_rejected_events() {
    let _g = lock();
    let q = tmp_dir("cap");
    let spec = sweep::selftest_spec();
    queue::enqueue(&q, "tenant", "a", &spec).unwrap();
    queue::enqueue(&q, "tenant", "b", &spec).unwrap();

    let mut o = opts(&q, 1);
    o.queue_cap = 1;
    let summary = daemon::run(&o).unwrap();
    assert_eq!(summary.merged, 1, "the in-cap spec must still run");
    assert_eq!(summary.rejected, 1, "the over-cap spec must be shed");
    assert!(q.join("reports").join("tenant__a.json").exists());
    assert!(!q.join("reports").join("tenant__b.json").exists());
    assert!(q.join("rejected").join("tenant__b.json").exists());
    let shed: Vec<_> = summary
        .events
        .iter()
        .filter_map(|e| match &e.kind {
            EventKind::SweepRejected { sweep, lane, depth, cap } => {
                Some((sweep.clone(), lane.clone(), *depth, *cap))
            }
            _ => None,
        })
        .collect();
    assert_eq!(shed, vec![("tenant__b".to_string(), "tenant".to_string(), 2, 1)]);
    std::fs::remove_dir_all(&q).unwrap();
}

#[test]
fn engine_requiring_specs_are_rejected_not_run() {
    let _g = lock();
    let q = tmp_dir("engine");
    let mut spec = SweepSpec::new("table2", TrainConfig::default());
    spec.push("v0".to_string(), "cola".to_string(), 1.0, "gauss", 1, 0);
    queue::enqueue(&q, "lane", "real", &spec).unwrap();

    let summary = daemon::run(&opts(&q, 1)).unwrap();
    assert_eq!(summary.merged, 0);
    assert_eq!(summary.rejected, 1);
    assert!(q.join("rejected").join("lane__real.json").exists());
    assert!(
        !summary.events.iter().any(|e| matches!(e.kind, EventKind::SweepStarted { .. })),
        "an engine-backed spec must be rejected before any work starts"
    );
    std::fs::remove_dir_all(&q).unwrap();
}

/// Crash = resume, through real processes: a seeded chaos kill takes
/// the daemon down mid-sweep (exit code 86), the spec stays parked in
/// `active/`, and a `--chaos-gen 1` restart (already-fired kills
/// filtered) finishes the missing cells to the identical report bytes.
#[test]
fn killed_daemon_resumes_to_the_identical_merged_report() {
    let _g = lock();
    let spec = sweep::synth_spec(7, "easy").unwrap();
    let serial = serial_report("crash_ref", &spec);
    let q = tmp_dir("crash_q");
    queue::enqueue(&q, "ci", "crash", &spec).unwrap();

    let exe = PathBuf::from(env!("CARGO_BIN_EXE_repro"));
    let run = |gen: u32| {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("sweep-daemon")
            .arg("--queue")
            .arg(&q)
            .arg("--drain")
            .arg("--lease-ttl-ms")
            .arg("1000")
            .arg("--chaos-seed")
            .arg("11")
            .arg("--chaos-profile")
            .arg("sched.cell@2=kill");
        if gen > 0 {
            cmd.arg("--chaos-gen").arg(gen.to_string());
        }
        cmd.output().expect("spawning sweep-daemon")
    };

    let first = run(0);
    assert_eq!(
        first.status.code(),
        Some(rmmlinear::chaos::KILL_EXIT_CODE),
        "the scheduled kill must take the daemon down\nstderr: {}",
        String::from_utf8_lossy(&first.stderr)
    );
    assert!(
        q.join("active").join("ci__crash.json").exists(),
        "a killed daemon must leave the dequeued spec in active/ for recovery"
    );

    let second = run(1);
    assert!(
        second.status.success(),
        "the gen-1 restart must finish the sweep\nstderr: {}",
        String::from_utf8_lossy(&second.stderr)
    );
    assert!(q.join("done").join("ci__crash.json").exists());
    let report = std::fs::read_to_string(q.join("reports").join("ci__crash.json")).unwrap();
    assert_eq!(report, serial, "crash + resume must publish the fault-free bytes");

    // The append-only tee now holds both runs (possibly with a line
    // torn by the kill): the parser still reads it, with monotonic ids
    // across the concatenation and two daemon_started markers.
    let log = std::fs::read_to_string(q.join("events.jsonl")).unwrap();
    let parsed = events::parse_lines(&log);
    assert_eq!(
        parsed.events.iter().filter(|e| matches!(e.kind, EventKind::DaemonStarted { .. })).count(),
        2
    );
    assert!((1..=u64::MAX).zip(&parsed.events).all(|(want, e)| e.id == want));
    std::fs::remove_dir_all(&q).unwrap();
}
