//! Memory sweep (paper §3.2, Fig. 3): measures the activation-store peak
//! across batch sizes and compression ratios, checks it against the
//! analytic model, and extrapolates to RoBERTa-base scale.
//!
//! ```bash
//! make artifacts && cargo run --release --example memory_sweep
//! ```

use std::path::Path;

use anyhow::Result;
use rmmlinear::config::TrainConfig;
use rmmlinear::coordinator::Trainer;
use rmmlinear::data::{Batcher, Split, Task, TaskGen, Tokenizer};
use rmmlinear::memory::{MemoryModel, ModelGeometry};
use rmmlinear::runtime::{Engine, Manifest};

fn main() -> Result<()> {
    let manifest = Manifest::load(Path::new("artifacts"))?;
    let mut engine = Engine::cpu()?;

    println!(
        "{:>6} {:>6} {:>15} {:>15} {:>8} {:>18}",
        "batch", "rho", "measured KiB", "model KiB", "err %", "roberta-base MiB"
    );
    for batch_size in [8usize, 16, 32, 64] {
        for rho_tag in [("r100", 1.0), ("r50", 0.5), ("r20", 0.2), ("r10", 0.1)] {
            let (tag, rho) = rho_tag;
            let vname = if batch_size == 16 {
                format!("small_cls2_{tag}_gauss")
            } else {
                format!("small_cls2_b{batch_size}_{tag}_gauss")
            };
            let variant = manifest.variant(&vname)?;
            let cfg = TrainConfig { steps: 2, warmup_steps: 0, ..Default::default() };
            let tok = Tokenizer::new(variant.config.vocab_size);
            let mut trainer = Trainer::new(&manifest, variant, Task::Cola, cfg)?;
            let gen = TaskGen::new(Task::Cola, &tok, variant.config.seq_len, 1);
            let mut batches = Batcher::new(&gen, Split::Train, batch_size, 0);
            for _ in 0..2 {
                let b = batches.next().unwrap();
                trainer.train_step(&mut engine, &b)?;
            }
            let measured = trainer.peak_residual_bytes;
            let model = MemoryModel::new(variant.config.geometry(), rho);
            let predicted = model.residual_bytes();
            let err = 100.0 * (measured as f64 - predicted as f64) / predicted as f64;
            let rob = MemoryModel::new(
                ModelGeometry::roberta_base(batch_size * 2, 128),
                rho,
            );
            println!(
                "{:>6} {:>6.2} {:>15.1} {:>15.1} {:>8.2} {:>18.1}",
                batch_size,
                rho,
                measured as f64 / 1024.0,
                predicted as f64 / 1024.0,
                err,
                rob.residual_bytes() as f64 / (1024.0 * 1024.0)
            );
            // The analytic model must match the measurement exactly (it
            // mirrors the tape layout); tolerate < 1% for float metadata.
            assert!(err.abs() < 1.0, "model/measurement divergence at {vname}");
        }
    }
    println!("\nanalytic model matches the measured activation store.");
    Ok(())
}
