//! Quickstart: load the AOT artifacts, fine-tune a randomized-linear (RMM)
//! model for a few steps on the CoLA-like task, and print loss + the
//! measured activation-store footprint vs the no-RMM baseline.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use rmmlinear::config::TrainConfig;
use rmmlinear::coordinator::Trainer;
use rmmlinear::data::{Batcher, Split, Task, TaskGen, Tokenizer};
use rmmlinear::runtime::{Engine, Manifest};

fn main() -> Result<()> {
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let mut engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());

    let cfg = TrainConfig { steps: 30, warmup_steps: 3, log_every: 5, ..Default::default() };
    let mut footprints = Vec::new();
    for variant_name in ["small_cls2_r100_gauss", "small_cls2_r10_gauss"] {
        let variant = manifest.variant(variant_name)?;
        let tok = Tokenizer::new(variant.config.vocab_size);
        let gen = TaskGen::new(Task::Cola, &tok, variant.config.seq_len, cfg.seed);
        let mut trainer = Trainer::new(&manifest, variant, Task::Cola, cfg.clone())?;

        println!(
            "\n=== {variant_name} (rho={}, sketch={}) ===",
            variant.config.rho, variant.config.sketch
        );
        let mut batches = Batcher::new(&gen, Split::Train, variant.config.batch_size, 0);
        for step in 0..cfg.steps {
            let batch = batches.next().unwrap();
            let s = trainer.train_step(&mut engine, &batch)?;
            if step % cfg.log_every == 0 || step + 1 == cfg.steps {
                println!(
                    "step {:>3}  loss {:.4}  residuals {:>7.1} KiB  ({:.0} ms/step)",
                    s.step,
                    s.loss,
                    s.residual_bytes as f64 / 1024.0,
                    s.step_time_s * 1e3
                );
            }
        }
        let score = trainer.evaluate(&mut engine, &tok)?;
        println!("dev Matthews corr: {score:.2}");
        footprints.push((variant_name, trainer.peak_residual_bytes));
    }

    let (base, rmm) = (footprints[0].1, footprints[1].1);
    println!(
        "\nstored activations: baseline {:.1} KiB -> rmm(rho=0.1) {:.1} KiB  ({:.1}% saved)",
        base as f64 / 1024.0,
        rmm as f64 / 1024.0,
        100.0 * (1.0 - rmm as f64 / base as f64)
    );
    Ok(())
}
