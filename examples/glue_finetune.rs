//! End-to-end driver (deliverable (b) + the mandated full-system example):
//! "pre-train" the encoder body on the MNLI-like corpus, then fine-tune on
//! a downstream task twice — baseline (no RMM) and randomized (ρ=0.5) —
//! logging the full loss curves, dev metric, throughput and measured
//! activation memory.  Exercises all three layers: Pallas-derived HLO via
//! PJRT (L1/L2) coordinated by the Rust trainer (L3).
//!
//! ```bash
//! make artifacts && cargo run --release --example glue_finetune -- [task] [steps]
//! ```
//!
//! Results of the reference run are recorded in EXPERIMENTS.md §E2E.

use std::path::Path;

use anyhow::{Context, Result};
use rmmlinear::bench_harness::runner::{head_for, run_finetune, variant_name, RunOpts};
use rmmlinear::config::TrainConfig;
use rmmlinear::coordinator::{Checkpoint, MetricsLog, Trainer};
use rmmlinear::data::{Batcher, Split, Task, TaskGen, Tokenizer};
use rmmlinear::runtime::{Engine, Manifest};
use rmmlinear::session::Session;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let task = Task::parse(args.first().map(|s| s.as_str()).unwrap_or("sst2"))
        .context("unknown task")?;
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);

    let manifest = Manifest::load(Path::new("artifacts"))?;
    let mut engine = Engine::cpu()?;

    // ---- phase 1: pre-train the encoder body on the largest corpus ----
    let pre_steps = steps.min(400);
    println!("=== phase 1: pretrain body on MNLI-like corpus ({pre_steps} steps) ===");
    let pre_variant = manifest.variant("small_cls3_r100_gauss")?;
    let pre_cfg = TrainConfig {
        steps: pre_steps,
        warmup_steps: pre_steps / 16,
        log_every: (pre_steps / 8).max(1),
        ..Default::default()
    };
    let tok = Tokenizer::new(pre_variant.config.vocab_size);
    let mut pre = Trainer::new(&manifest, pre_variant, Task::Mnli, pre_cfg.clone())?;
    let gen = TaskGen::new(Task::Mnli, &tok, pre_variant.config.seq_len, pre_cfg.seed);
    let mut epoch = 0;
    let mut batches = Batcher::new(&gen, Split::Train, pre_variant.config.batch_size, 0);
    for step in 0..pre_steps {
        let batch = match batches.next() {
            Some(b) => b,
            None => {
                epoch += 1;
                batches = Batcher::new(&gen, Split::Train, pre_variant.config.batch_size, epoch);
                batches.next().unwrap()
            }
        };
        let s = pre.train_step(&mut engine, &batch)?;
        if step % pre_cfg.log_every == 0 {
            println!("  pretrain step {:>4}  loss {:.4}", step, s.loss);
        }
    }
    println!("  pretrain dev acc: {:.2}", pre.evaluate(&mut engine, &tok)?);
    let body = Checkpoint {
        step: pre_steps,
        variant: "small_cls3_r100_gauss".into(),
        names: pre.param_names.clone(),
        params: pre.params.clone(),
    };
    drop(pre); // release the manifest borrow before the session takes it

    // ---- phase 2: fine-tune downstream, baseline vs RMM ----
    // Both fine-tunes run through one warm session: the second reuses the
    // first's tokenizer and, at equal variants, compiled executables.
    let mut session = Session::new(engine, manifest, true);
    let out = Path::new("runs/glue_finetune");
    std::fs::create_dir_all(out)?;
    let mut results = Vec::new();
    for rho in [1.0, 0.5] {
        let vname = variant_name("small", head_for(task), rho, "gauss");
        println!("\n=== phase 2: fine-tune {} with rho={rho} ({steps} steps) ===", task.name());
        let mut log =
            MetricsLog::create(&out.join(format!("{}_rho{rho}.jsonl", task.name())))?;
        let cfg = TrainConfig {
            steps,
            warmup_steps: steps / 16,
            log_every: (steps / 20).max(1),
            ..Default::default()
        };
        let res = run_finetune(
            &mut session,
            &vname,
            task,
            RunOpts {
                train: cfg,
                log: Some(&mut log),
                eval_loss_every: (steps / 10).max(1),
                warm_start: Some((&body.names, &body.params)),
                skip_eval: false,
                tick: None,
            },
        )?;
        println!(
            "  rho={rho}: dev score {:.2}, {:.1} samples/s, peak residuals {:.1} KiB",
            res.score,
            res.samples_per_s,
            res.peak_residual_bytes as f64 / 1024.0
        );
        results.push(res);
    }

    println!("\n=== summary ===");
    println!(
        "{:<10} {:>8} {:>12} {:>14} {:>12}",
        "mode", "score", "samples/s", "resid KiB", "train loss"
    );
    for r in &results {
        println!(
            "{:<10} {:>8.2} {:>12.1} {:>14.1} {:>12.4}",
            if (r.rho - 1.0).abs() < 1e-9 { "baseline" } else { "rmm(0.5)" },
            r.score,
            r.samples_per_s,
            r.peak_residual_bytes as f64 / 1024.0,
            r.final_train_loss
        );
    }
    let saved = 100.0
        * (1.0 - results[1].peak_residual_bytes as f64
            / results[0].peak_residual_bytes as f64);
    println!("\nactivation memory saved by RMM at rho=0.5: {saved:.1}%");
    println!("loss curves -> {}", out.display());
    Ok(())
}
