//! Variance monitor (paper §3.3, Fig. 4/7): fine-tunes the probe variant
//! and live-prints the paper's variance estimators at the probed layer —
//! D²_SGD (Lemma 2.1), D²_RMM (Lemma 2.2), α, and both sides of
//! Theorem 2.3's inequality — asserting the bound at every step.
//!
//! ```bash
//! make artifacts && cargo run --release --example variance_monitor -- [steps]
//! ```

use std::path::Path;

use anyhow::Result;
use rmmlinear::config::TrainConfig;
use rmmlinear::coordinator::Trainer;
use rmmlinear::data::{Batcher, Split, Task, TaskGen, Tokenizer};
use rmmlinear::runtime::{Engine, Manifest};

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);
    let manifest = Manifest::load(Path::new("artifacts"))?;
    let mut engine = Engine::cpu()?;
    let variant = manifest.variant("probe_cls2_r50_gauss")?;
    let cfg = TrainConfig { steps, warmup_steps: steps / 16, ..Default::default() };
    let tok = Tokenizer::new(variant.config.vocab_size);
    let mut trainer = Trainer::new(&manifest, variant, Task::Cola, cfg.clone())?;
    let gen = TaskGen::new(Task::Cola, &tok, variant.config.seq_len, cfg.seed);

    println!(
        "probing FFN1 of block {} (rows={}, b_proj={})",
        variant.config.probe_layer, variant.rows, variant.b_proj
    );
    println!(
        "{:>5} {:>9} {:>13} {:>13} {:>9} {:>10} {:>10}",
        "step", "loss", "d2_sgd", "d2_rmm", "alpha", "ratio_lhs", "bound_rhs"
    );
    let mut epoch = 0;
    let mut batches = Batcher::new(&gen, Split::Train, variant.config.batch_size, 0);
    let mut violations = 0;
    for step in 0..steps {
        let batch = match batches.next() {
            Some(b) => b,
            None => {
                epoch += 1;
                batches = Batcher::new(&gen, Split::Train, variant.config.batch_size, epoch);
                batches.next().unwrap()
            }
        };
        let s = trainer.train_step(&mut engine, &batch)?;
        let p = s.probe.expect("probe variant must emit probe stats");
        if p.ratio_lhs > p.bound_rhs * 1.001 {
            violations += 1;
        }
        if step % (steps / 20).max(1) == 0 || step + 1 == steps {
            println!(
                "{:>5} {:>9.4} {:>13.4e} {:>13.4e} {:>9.4} {:>10.4} {:>10.2}",
                step, s.loss, p.d2_sgd, p.d2_rmm, p.alpha, p.ratio_lhs, p.bound_rhs
            );
        }
    }
    println!("\nTheorem 2.3 bound violations: {violations}/{steps}");
    assert_eq!(violations, 0, "the variance bound must hold empirically");
    println!("OK: ratio stayed below (alpha+1)/alpha at every step");
    Ok(())
}
